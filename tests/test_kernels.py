"""Per-kernel validation: shape/dtype sweeps, allclose vs the pure-jnp
ref.py oracles (kernels execute in interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade property tests to fixed-example tests
    from _hypothesis_compat import given, settings, st

from repro.kernels.adaln.ops import adaln_modulate
from repro.kernels.adaln.ref import adaln_modulate_ref
from repro.kernels.flash.ops import flash_attention
from repro.kernels.flash.ref import attention_ref
from repro.kernels.reuse_mask.ops import reuse_snap
from repro.kernels.reuse_mask.ref import reuse_snap_ref
from repro.kernels.ripple.ops import ripple_attention_pallas, ripple_block_stats
from repro.kernels.ripple.ref import ripple_attention_ref
from repro.kernels.sparse.ops import (FULL, PARTIAL, SKIP,
                                      block_map_from_keep,
                                      sparse_attention_pallas,
                                      sparse_block_stats)
from repro.kernels.sparse.ref import expand_block_map, sparse_attention_ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 3e-5


class TestFlashKernel:
    @pytest.mark.parametrize("B,H,Nq,Nk,d,dv", [
        (1, 1, 128, 128, 64, 64),
        (2, 3, 256, 256, 32, 32),
        (1, 2, 200, 333, 16, 48),   # unaligned both dims
        (1, 1, 64, 512, 128, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, B, H, Nq, Nk, d, dv, dtype):
        ks = jax.random.split(jax.random.PRNGKey(Nq + Nk + d), 3)
        q = jax.random.normal(ks[0], (B, H, Nq, d), dtype)
        k = jax.random.normal(ks[1], (B, H, Nk, d), dtype)
        v = jax.random.normal(ks[2], (B, H, Nk, dv), dtype)
        out = flash_attention(q, k, v, block_q=128, block_k=128)
        ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=_tol(dtype),
                                   rtol=1e-2)

    def test_extreme_logits_stable(self):
        q = 30.0 * jax.random.normal(jax.random.PRNGKey(0), (1, 1, 128, 32))
        k = 30.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 1, 128, 32))
        v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 128, 32))
        out = flash_attention(q, k, v)
        assert np.isfinite(np.asarray(out)).all()


def _snapped_operand(key, B, H, N, d, frac):
    x = jax.random.normal(key, (B, H, N, d))
    e, o = x[..., 0::2, :], x[..., 1::2, :]
    coll = jax.random.uniform(jax.random.fold_in(key, 1),
                              (B, H, N // 2, 1)) < frac
    return jnp.stack([e, jnp.where(coll, e, o)], 3).reshape(B, H, N, d)


class TestRippleKernel:
    @pytest.mark.parametrize("N,d,frac", [
        (256, 32, 0.0), (256, 32, 0.6), (256, 32, 1.0),
        (512, 64, 0.9), (130, 16, 1.0),  # unaligned pairs
    ])
    def test_matches_snapped_oracle(self, N, d, frac):
        q = _snapped_operand(jax.random.PRNGKey(1), 1, 2, N, d, frac)
        k = _snapped_operand(jax.random.PRNGKey(2), 1, 2, N, d, frac)
        v = jax.random.normal(jax.random.PRNGKey(3), (1, 2, N, d))
        out = ripple_attention_pallas(q, k, v, block_q=64, block_k=64)
        ref = ripple_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    def test_structural_savings_reach_75_when_fully_collapsed(self):
        q = _snapped_operand(jax.random.PRNGKey(4), 1, 1, 512, 32, 1.0)
        k = _snapped_operand(jax.random.PRNGKey(5), 1, 1, 512, 32, 1.0)
        s = float(ripple_block_stats(q, k, block_q=64, block_k=64))
        assert abs(s - 0.75) < 1e-6

    def test_zero_savings_when_nothing_collapses(self):
        q = _snapped_operand(jax.random.PRNGKey(6), 1, 1, 512, 32, 0.0)
        k = _snapped_operand(jax.random.PRNGKey(7), 1, 1, 512, 32, 0.0)
        assert float(ripple_block_stats(q, k)) == 0.0

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_property_random_pair_structure(self, seed):
        key = jax.random.PRNGKey(seed)
        frac = float(jax.random.uniform(key))
        q = _snapped_operand(jax.random.fold_in(key, 1), 1, 1, 128, 16, frac)
        k = _snapped_operand(jax.random.fold_in(key, 2), 1, 1, 128, 16, frac)
        v = jax.random.normal(jax.random.fold_in(key, 3), (1, 1, 128, 16))
        out = ripple_attention_pallas(q, k, v, block_q=32, block_k=32)
        ref = ripple_attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)


def _sparse_qkv(seed, B=1, H=2, N=256, D=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(kk, (B, H, N, D)) for kk in ks)


class TestSparseKernel:
    """Block-sparse masked flash kernel vs its pure-jnp oracle for every
    block-map state, plus the block-map-from-keep consistency contract
    (DESIGN.md §12)."""

    def test_all_full_matches_dense(self):
        q, k, v = _sparse_qkv(0)
        bmap = jnp.full((4, 4), FULL, jnp.int32)
        out = sparse_attention_pallas(q, k, v, block_map=bmap,
                                      block_q=64, block_k=64)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)
        assert float(sparse_block_stats(bmap)) == 0.0

    def test_all_skip_emits_zeros(self):
        q, k, v = _sparse_qkv(1)
        bmap = jnp.full((4, 4), SKIP, jnp.int32)
        out = sparse_attention_pallas(q, k, v, block_map=bmap,
                                      block_q=64, block_k=64)
        assert not np.asarray(out).any()
        ref = sparse_attention_ref(q, k, v, block_map=bmap,
                                   block_q=64, block_k=64)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert float(sparse_block_stats(bmap)) == 1.0

    def test_mixed_map_matches_oracle(self):
        q, k, v = _sparse_qkv(2)
        keep = jax.random.bernoulli(jax.random.PRNGKey(3), 0.5,
                                    (1, 2, 256, 256))
        keep = keep.at[..., :64, :64].set(True)    # a FULL tile
        keep = keep.at[..., 64:128, :64].set(False)  # a SKIP tile
        bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        bmap = block_map_from_keep(keep, 64, 64)
        assert {int(s) for s in np.unique(np.asarray(bmap))} \
            == {SKIP, FULL, PARTIAL}
        out = sparse_attention_pallas(q, k, v, bias=bias, block_map=bmap,
                                      block_q=64, block_k=64)
        ref = sparse_attention_ref(q, k, v, bias=bias, block_map=bmap,
                                   block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)
        # a map consistent with its bias also matches the plain dense
        # masked softmax (every row keeps at least one key here)
        dense = sparse_attention_ref(q, k, v, bias=bias,
                                     block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   atol=3e-5)

    def test_partial_bias_applied_in_kernel(self):
        q, k, v = _sparse_qkv(4)
        bias = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 256, 256))
        bmap = jnp.full((4, 4), PARTIAL, jnp.int32)
        out = sparse_attention_pallas(q, k, v, bias=bias, block_map=bmap,
                                      block_q=64, block_k=64)
        s = (jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32)
             * (1.0 / np.sqrt(q.shape[-1]))) + bias
        p = jax.nn.softmax(s, axis=-1)
        ref = jnp.einsum("...qk,...kv->...qv", p, v.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    @pytest.mark.parametrize("N", [200, 130])
    def test_unaligned_tokens_padded_correctly(self, N):
        q, k, v = _sparse_qkv(6, N=N)
        keep = jax.random.bernoulli(jax.random.PRNGKey(7), 0.6,
                                    (1, 2, N, N))
        keep = keep.at[..., 64:128, :64].set(False)
        bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        bmap = block_map_from_keep(keep, 64, 64)
        out = sparse_attention_pallas(q, k, v, bias=bias, block_map=bmap,
                                      block_q=64, block_k=64)
        ref = sparse_attention_ref(q, k, v, bias=bias, block_map=bmap,
                                   block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    def test_no_map_degrades_to_masked_dense(self):
        """block_map=None + bias: every tile runs PARTIAL (dense masked
        flash); block_map=None + no bias: plain flash."""
        q, k, v = _sparse_qkv(8)
        keep = jax.random.bernoulli(jax.random.PRNGKey(9), 0.7,
                                    (1, 2, 256, 256))
        bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        out = sparse_attention_pallas(q, k, v, bias=bias,
                                      block_q=64, block_k=64)
        ref = sparse_attention_ref(q, k, v, bias=bias,
                                   block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)
        out2 = sparse_attention_pallas(q, k, v, block_q=64, block_k=64)
        np.testing.assert_allclose(np.asarray(out2),
                                   np.asarray(attention_ref(q, k, v)),
                                   atol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_property_block_map_consistency(self, seed):
        """For any keep-mask: FULL tiles keep everything, SKIP tiles
        nothing, and the kernel on (map, bias) matches the dense masked
        softmax wherever a row keeps at least one key."""
        key = jax.random.PRNGKey(seed)
        density = float(jax.random.uniform(key, minval=0.05, maxval=0.95))
        N, blk = 128, 32
        keep = jax.random.bernoulli(jax.random.fold_in(key, 1), density,
                                    (1, 1, N, N))
        bmap = block_map_from_keep(keep, blk, blk)
        st_tok = np.asarray(expand_block_map(bmap, N, N, blk, blk))
        keep_np = np.asarray(keep)
        assert keep_np[st_tok == FULL].all()
        assert not keep_np[st_tok == SKIP].any()
        q, k, v = _sparse_qkv(seed + 1, H=1, N=N, D=16)
        bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        out = np.asarray(sparse_attention_pallas(
            q, k, v, bias=bias, block_map=bmap, block_q=blk, block_k=blk))
        ref = np.asarray(sparse_attention_ref(
            q, k, v, bias=bias, block_q=blk, block_k=blk))
        rows_alive = keep_np.any(axis=-1)
        np.testing.assert_allclose(out[rows_alive], ref[rows_alive],
                                   atol=3e-5)
        # fully-masked rows: the kernel's zero convention, never NaN
        assert np.isfinite(out).all()
        assert not out[~rows_alive].any()


class TestReuseSnapKernel:
    @pytest.mark.parametrize("N,d", [(256, 16), (300, 32), (64, 128)])
    @pytest.mark.parametrize("theta", [0.0, 0.3, 10.0])
    def test_matches_oracle(self, N, d, theta):
        x = jax.random.normal(jax.random.PRNGKey(N + d), (2, 2, N, d))
        snapped, mask = reuse_snap(x, theta, block=64)
        ref_o, ref_m = reuse_snap_ref(x[..., 0::2, :], x[..., 1::2, :], theta)
        np.testing.assert_allclose(np.asarray(snapped[..., 1::2, :]),
                                   np.asarray(ref_o))
        np.testing.assert_array_equal(np.asarray(mask[..., 1::2, :]),
                                      np.asarray(ref_m))
        # representatives untouched, never masked
        np.testing.assert_array_equal(np.asarray(snapped[..., 0::2, :]),
                                      np.asarray(x[..., 0::2, :]))
        assert not np.asarray(mask[..., 0::2, :]).any()


class TestAdaLNKernel:
    @pytest.mark.parametrize("B,Ntok,d", [(2, 256, 64), (1, 100, 128),
                                          (4, 64, 32)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, B, Ntok, d, dtype):
        x = jax.random.normal(jax.random.PRNGKey(0), (B, Ntok, d), dtype)
        sh = jax.random.normal(jax.random.PRNGKey(1), (B, d), dtype)
        sc = jax.random.normal(jax.random.PRNGKey(2), (B, d), dtype)
        out = adaln_modulate(x, sh, sc, block_t=64)
        ref = adaln_modulate_ref(x, sh, sc)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=_tol(dtype), rtol=1e-2)
