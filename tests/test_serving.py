"""Continuous-batching engine tests: per-request RNG threading, mixed
(resolution, steps) traffic from concurrent submitters, bucket purity,
the compiled-sampler LRU, clean drain on stop(), and the PR-7 bugfix
regressions (mixed prompt lengths, errored-result retrievability,
LMEngine argument validation, event-driven linger, chunked
streaming)."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import DiffusionEngine, GenRequest, LMEngine


def _txt(val, tokens=1, dim=1):
    return np.full((tokens, dim), float(val), np.float32)


class TestPerRequestRNG:
    def test_seeds_differ_within_one_batch(self):
        """Regression for the seed bug: sample_fn used to receive
        rngs[0], collapsing every request's sampler randomness onto the
        first request's key.  A sampler that depends ONLY on the rng
        argument must now produce different latents for different seeds
        served in the same batch."""
        batches = []

        def sample_fn(noise, txt, rngs):
            batches.append(noise.shape[0])
            assert rngs.shape == (noise.shape[0], 2)  # full key batch
            return jax.vmap(
                lambda k: jax.random.normal(k, noise.shape[1:]))(rngs)

        eng = DiffusionEngine(sample_fn, latent_shape=(4,), max_batch=4,
                              max_wait_s=0.5)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), seed=0))
        eng.submit(GenRequest(request_id=1, txt=_txt(0), seed=1))
        r0 = eng.result(0, timeout=30)
        r1 = eng.result(1, timeout=30)
        eng.stop()
        assert 2 in batches  # both requests really shared one batch
        assert not np.allclose(r0.latents, r1.latents)

    def test_seed_determinism_across_batches(self):
        def sample_fn(noise, txt, rngs):
            return jax.vmap(
                lambda k: jax.random.normal(k, noise.shape[1:]))(rngs)

        eng = DiffusionEngine(sample_fn, latent_shape=(4,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), seed=7))
        a = eng.result(0, timeout=30).latents
        eng.submit(GenRequest(request_id=1, txt=_txt(0), seed=7))
        b = eng.result(1, timeout=30).latents
        eng.stop()
        np.testing.assert_array_equal(a, b)


class TestMixedTrafficConcurrency:
    BUCKETS = (((2, 2, 1), 2), ((4, 4, 1), 3), ((2, 2, 1), 3))

    def test_threads_mixed_shapes_all_complete(self):
        """Multiple submitter threads, heterogeneous (resolution, steps)
        traffic: every request completes, results map back to the right
        request_id, and no sampler invocation ever mixes shapes."""
        served = []

        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                # bucket purity: the whole batch matches this bucket
                assert noise.shape[1:] == latent_shape
                assert txt.shape[0] == noise.shape[0] == rngs.shape[0]
                served.append((latent_shape, steps, noise.shape[0]))
                # encode (request marker, steps) into the output
                return (jnp.zeros_like(noise)
                        + txt[:, 0, 0].reshape((-1,) + (1,) * (noise.ndim - 1))
                        + 1000.0 * steps)
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02)
        eng.start()
        n_threads, per_thread = 4, 8
        expected = {}

        def submit(tid):
            rng = np.random.default_rng(tid)
            for j in range(per_thread):
                rid = tid * 100 + j
                shape, steps = self.BUCKETS[rng.integers(len(self.BUCKETS))]
                expected[rid] = (shape, steps)
                eng.submit(GenRequest(request_id=rid, txt=_txt(rid),
                                      steps=steps, seed=rid,
                                      latent_shape=shape))
                time.sleep(0.001 * int(rng.integers(3)))

        threads = [threading.Thread(target=submit, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        for rid, (shape, steps) in expected.items():
            r = eng.result(rid, timeout=60)
            assert r.latents.shape == shape
            np.testing.assert_allclose(r.latents,
                                       float(rid) + 1000.0 * steps)
        eng.stop()
        assert sum(b for _, _, b in served) == n_threads * per_thread
        # every served batch drew from exactly one bucket (asserted in
        # fn); batching actually happened under concurrent submission
        assert len(served) <= n_threads * per_thread

    def test_hottest_bucket_drains_first(self):
        order = []

        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                order.append((latent_shape, noise.shape[0]))
                return noise
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=8,
                              max_wait_s=0.05)
        # queue before starting: 1 request cold bucket, 3 hot bucket
        eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=2,
                              latent_shape=(2, 2)))
        for i in range(1, 4):
            eng.submit(GenRequest(request_id=i, txt=_txt(i), steps=2,
                                  latent_shape=(4, 4)))
        eng.start()
        for i in range(4):
            eng.result(i, timeout=30)
        eng.stop()
        assert order[0] == ((4, 4), 3)  # deepest queue served first

    def test_cold_bucket_not_starved_by_hot_traffic(self):
        """Aging guard: a lone request in a cold bucket is served within
        ~starve_after_s even while fresh hot-bucket traffic keeps that
        bucket deeper the whole time (pure hottest-first would starve the
        cold request until the hot stream dries up)."""
        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                time.sleep(0.02)
                return noise
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.01, starve_after_s=0.2)
        eng.start()
        # warm both shapes so first-call tracing doesn't skew timing
        eng.submit(GenRequest(request_id=9000, txt=_txt(0), steps=2,
                              latent_shape=(4, 4)))
        eng.submit(GenRequest(request_id=9001, txt=_txt(0), steps=2,
                              latent_shape=(2, 2)))
        eng.result(9000, timeout=30)
        eng.result(9001, timeout=30)

        stop_feed = threading.Event()

        def feeder():  # keep the hot bucket continuously refilled
            rid = 1
            while not stop_feed.is_set():
                if eng.pending() < 8:
                    for _ in range(4):
                        eng.submit(GenRequest(request_id=rid, txt=_txt(rid),
                                              steps=2, latent_shape=(4, 4)))
                        rid += 1
                time.sleep(0.005)

        t = threading.Thread(target=feeder)
        t.start()
        try:
            time.sleep(0.1)  # hot traffic flowing
            eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=2,
                                  latent_shape=(2, 2)))
            r = eng.result(0, timeout=3.0)  # << the feeder's lifetime
            assert r.latents.shape == (2, 2)
        finally:
            stop_feed.set()
            t.join()
            eng.stop(drain=False)

    def test_compiled_sampler_lru_bounded_keeps_hottest(self):
        builds = []

        def factory(latent_shape, steps):
            builds.append((latent_shape, steps))
            return lambda noise, txt, rngs: noise

        eng = DiffusionEngine(sampler_factory=factory, max_batch=2,
                              max_wait_s=0.01, max_compiled=2)
        eng.start()
        rid = 0
        # bucket keys carry the policy name, reuse cadence, the
        # dispatch mesh's seq-shard degree (1 = no ring), the text-
        # embedding shape, the streaming cadence (None = monolithic),
        # and the policy's plan token (pattern-artifact version)
        hot = ((2, 2), 2, None, None, 1, (1, 1), None, None)
        for round_ in range(3):
            for shape, steps in ((hot[0], hot[1]), ((4, 4), 2), ((8, 8), 2)):
                eng.submit(GenRequest(request_id=rid, txt=_txt(rid),
                                      steps=steps, latent_shape=shape))
                eng.result(rid, timeout=30)
                rid += 1
            # the hot bucket is touched again right away each round
            eng.submit(GenRequest(request_id=rid, txt=_txt(rid), steps=2,
                                  latent_shape=(2, 2)))
            eng.result(rid, timeout=30)
            rid += 1
            assert len(eng._compiled) <= 2
            assert hot in eng._compiled  # hottest entry survives eviction
        eng.stop()
        assert len(builds) > 3  # eviction forced rebuilds of cold buckets


class TestStopSemantics:
    def test_stop_drains_cleanly(self):
        """stop() serves everything already queued before joining — no
        result is orphaned under the lock."""
        def sample_fn(noise, txt, rngs):
            time.sleep(0.02)
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=2,
                              max_wait_s=0.01)
        eng.start()
        for i in range(6):
            eng.submit(GenRequest(request_id=i, txt=_txt(i), seed=i))
        eng.stop()  # backlog still queued at this point
        assert eng.pending() == 0
        for i in range(6):
            r = eng.result(i, timeout=1.0)  # already resolved, no wait
            assert r.latents.shape == (2,)

    def test_submit_after_stop_raises(self):
        eng = DiffusionEngine(lambda n, t, r: n, latent_shape=(2,))
        eng.start()
        eng.stop()
        with pytest.raises(RuntimeError):
            eng.submit(GenRequest(request_id=0, txt=_txt(0)))

    def test_failed_batch_reports_error_not_hang(self):
        def sample_fn(noise, txt, rngs):
            raise ValueError("boom")

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=2,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        with pytest.raises(RuntimeError, match="boom"):
            eng.result(0, timeout=30)
        eng.stop()


class TestMixedPromptLengths:
    def test_mixed_txt_shapes_do_not_crash_the_batch(self):
        """Regression: two requests with the same latent shape but
        different prompt lengths L used to land in one bucket, and
        ``jnp.stack([r.txt ...])`` failed the whole batch at stack
        time.  The text-embedding shape is bucket identity now, so both
        requests are served (in separate, shape-pure batches)."""
        served_txt_shapes = []

        def sample_fn(noise, txt, rngs):
            served_txt_shapes.append(txt.shape[1:])
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(4,), max_batch=4,
                              max_wait_s=0.2)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0, tokens=2)))
        eng.submit(GenRequest(request_id=1, txt=_txt(1, tokens=3)))
        r0 = eng.result(0, timeout=30)
        r1 = eng.result(1, timeout=30)
        eng.stop()
        assert r0.latents.shape == (4,) and r1.latents.shape == (4,)
        assert sorted(served_txt_shapes) == [(2, 1), (3, 1)]

    def test_same_txt_shape_still_shares_a_batch(self):
        batches = []

        def sample_fn(noise, txt, rngs):
            batches.append(noise.shape[0])
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(4,), max_batch=4,
                              max_wait_s=0.5)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0, tokens=2)))
        eng.submit(GenRequest(request_id=1, txt=_txt(1, tokens=2)))
        eng.result(0, timeout=30)
        eng.result(1, timeout=30)
        eng.stop()
        assert 2 in batches


class TestSubmitValidation:
    def test_rejects_malformed_requests_at_the_door(self):
        """§17 satellite: a malformed field used to stack fine and then
        crash the sampler, failing every batchmate.  Now submit()
        raises ValueError before the request is queued."""
        eng = DiffusionEngine(lambda n, t, r: n, latent_shape=(2,))
        eng.start()
        with pytest.raises(ValueError, match="steps"):
            eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=0))
        with pytest.raises(ValueError, match="steps"):
            eng.submit(GenRequest(request_id=1, txt=_txt(1), steps=-3))
        with pytest.raises(ValueError, match="latent_shape"):
            eng.submit(GenRequest(request_id=2, txt=_txt(2),
                                  latent_shape=(0, 2)))
        with pytest.raises(ValueError, match="latent_shape"):
            eng.submit(GenRequest(request_id=3, txt=_txt(3),
                                  latent_shape=()))
        with pytest.raises(ValueError, match="deadline_s"):
            eng.submit(GenRequest(request_id=4, txt=_txt(4),
                                  deadline_s=-5.0))
        with pytest.raises(ValueError, match="reuse_every"):
            eng.submit(GenRequest(request_id=5, txt=_txt(5),
                                  reuse_every=0))
        with pytest.raises(ValueError, match="stream_every"):
            eng.submit(GenRequest(request_id=6, txt=_txt(6),
                                  stream_every=-1))
        assert eng.pending() == 0  # nothing malformed was queued
        eng.stop()


class TestErroredResultRetrievable:
    def test_retry_after_error_sees_original_error_not_timeout(self):
        """Regression: ``result()`` used to *pop* an errored result
        before raising, so a caller that caught the error (or a
        TimeoutError) and retried got a misleading TimeoutError instead
        of the original batch error."""
        def sample_fn(noise, txt, rngs):
            raise ValueError("boom-original")

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        for _ in range(3):  # every retry sees the original batch error
            with pytest.raises(RuntimeError, match="boom-original"):
                eng.result(0, timeout=30)
        eng.stop()

    def test_errored_result_evicted_after_ttl(self):
        def sample_fn(noise, txt, rngs):
            raise ValueError("boom")

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01, error_ttl_s=0.1)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        with pytest.raises(RuntimeError, match="boom"):
            eng.result(0, timeout=30)
        eng.stop()
        time.sleep(0.15)
        with pytest.raises(TimeoutError):
            eng.result(0, timeout=0.05)

    def test_error_tombstone_lives_through_its_expiry_instant(
            self, monkeypatch):
        """Regression (§17 satellite): the eviction predicate used to be
        ``exp <= now``, so a result() retry landing exactly at the TTL
        expiry instant evicted the very tombstone it came for and raised
        a spurious TimeoutError instead of the stored batch error."""
        import repro.serving.engine as engine_mod

        def sample_fn(noise, txt, rngs):
            raise ValueError("boom-ttl")

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01, error_ttl_s=60.0)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        with pytest.raises(RuntimeError, match="boom-ttl"):
            eng.result(0, timeout=30)
        eng.stop()
        # Retry landing *exactly* at the expiry instant: still the error.
        frozen = eng._error_expiry[0]
        monkeypatch.setattr(engine_mod.time, "time", lambda: frozen)
        with pytest.raises(RuntimeError, match="boom-ttl"):
            eng.result(0, timeout=30)
        monkeypatch.undo()
        # Strictly after the instant: evicted, back to TimeoutError.
        eng._error_expiry[0] = time.time() - 0.001
        with pytest.raises(TimeoutError):
            eng.result(0, timeout=0.01)

    def test_result_timeout_is_clamped_nonnegative(self):
        """A result() deadline in the past must raise TimeoutError
        cleanly (the old code handed Condition.wait a negative
        timeout)."""
        eng = DiffusionEngine(lambda n, t, r: n, latent_shape=(2,))
        eng.start()
        with pytest.raises(TimeoutError):
            eng.result(123, timeout=-0.5)
        with pytest.raises(TimeoutError):
            eng.result(123, timeout=0.0)
        eng.stop()


class TestLMEngineValidation:
    def _engine(self, max_len=8):
        V = 5

        def prefill(tokens):
            B, S = tokens.shape
            return jnp.zeros((B, S, V)), {}

        def decode(tok, cache, idx):
            return jnp.zeros((tok.shape[0], 1, V)), cache

        return LMEngine(prefill, decode, max_len=max_len)

    def test_temperature_without_rng_raises(self):
        """Regression: temperature > 0 with the default rng=None used to
        crash inside jax.random.split(None)."""
        eng = self._engine()
        toks = jnp.zeros((1, 2), jnp.int32)
        with pytest.raises(ValueError, match="rng"):
            eng.generate(toks, num_new=2, temperature=0.7)

    def test_temperature_with_rng_works(self):
        eng = self._engine()
        toks = jnp.zeros((1, 2), jnp.int32)
        out = eng.generate(toks, num_new=2, temperature=0.7,
                           rng=jax.random.PRNGKey(0))
        assert out.shape == (1, 2)

    def test_max_len_enforced(self):
        """Regression: max_len was stored but never enforced — prompt +
        num_new could silently exceed the KV-cache allocation."""
        eng = self._engine(max_len=8)
        toks = jnp.zeros((1, 6), jnp.int32)
        with pytest.raises(ValueError, match="max_len"):
            eng.generate(toks, num_new=3)
        assert eng.generate(toks, num_new=2).shape == (1, 2)


class TestEventDrivenLinger:
    def test_linger_does_not_busy_poll(self, monkeypatch):
        """Regression: _take_batch's linger loop busy-polled with
        time.sleep(0.005).  Batch-mate arrival must wake it through the
        condition variable instead — the batcher thread never calls
        time.sleep."""
        sleep_threads = []
        real_sleep = time.sleep

        def spy(seconds):
            sleep_threads.append(threading.current_thread())
            real_sleep(seconds)

        monkeypatch.setattr(time, "sleep", spy)
        batches = []

        def sample_fn(noise, txt, rngs):
            batches.append(noise.shape[0])
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=2,
                              max_wait_s=1.0)
        eng.start()
        batcher = eng._thread
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        real_sleep(0.05)  # batcher is now lingering for a batch-mate
        t0 = time.time()
        eng.submit(GenRequest(request_id=1, txt=_txt(1)))
        eng.result(0, timeout=30)
        eng.result(1, timeout=30)
        waited = time.time() - t0
        eng.stop()
        assert 2 in batches          # the linger really batched them
        assert batcher not in sleep_threads  # and never slept to poll
        # arrival filled the batch => the linger ended well before its
        # 1s budget (event-driven, not deadline-driven)
        assert waited < 0.8


class TestStreamingDelivery:
    @staticmethod
    def _factory(latent_shape, steps, policy=None, reuse_every=None,
                 stream_every=None):
        if stream_every is None:
            return lambda noise, txt, rngs: noise

        def gen_fn(noise, txt, rngs):
            for k in range(1, 4):  # 3 chunks, last one is final
                time.sleep(0.03)
                yield noise + k, {"chunk": k}

        return gen_fn

    def test_stream_yields_chunks_then_result(self):
        eng = DiffusionEngine(sampler_factory=self._factory,
                              latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), stream_every=1))
        chunks = list(eng.stream(0, timeout=30))
        r = eng.result(0, timeout=30)
        eng.stop()
        assert len(chunks) == 3
        np.testing.assert_allclose(chunks[-1], r.latents)
        assert not np.allclose(chunks[0], chunks[-1])

    def test_ttff_beats_completion(self):
        eng = DiffusionEngine(sampler_factory=self._factory,
                              latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), stream_every=1))
        r = eng.result(0, timeout=30)
        eng.stop()
        assert 0 <= r.ttff_s < r.walltime_s  # first frame landed early

    def test_stream_terminates_after_result_consumed(self):
        """REVIEW regression: result() popping the record (and the
        partials) used to leave a stream consumer with no termination
        signal — it hung until TimeoutError.  The finished tombstone
        keeps the chunks readable and ends the stream cleanly."""
        eng = DiffusionEngine(sampler_factory=self._factory,
                              latent_shape=(2,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), stream_every=1))
        r = eng.result(0, timeout=30)            # consumes the record
        chunks = list(eng.stream(0, timeout=2))  # must not hang
        eng.stop()
        assert len(chunks) == 3
        np.testing.assert_allclose(chunks[-1], r.latents)

    def test_stream_every_requires_capable_factory(self):
        eng = DiffusionEngine(lambda n, t, r: n, latent_shape=(2,))
        eng.start()
        with pytest.raises(ValueError, match="stream_every"):
            eng.submit(GenRequest(request_id=0, txt=_txt(0),
                                  stream_every=2))
        eng.stop()

    def test_degraded_state_survives_streaming_chunk_boundary(self):
        """§17: a NaN chunk mid-stream trips the ladder *before*
        publication — subscribers never see the bad frame — and the
        batch re-serves under the dense rung without re-publishing the
        chunks the first attempt already delivered."""
        def factory(latent_shape, steps, policy=None, reuse_every=None,
                    stream_every=None):
            if stream_every is None:
                return lambda noise, txt, rngs: noise

            def gen_fn(noise, txt, rngs):
                for k in range(1, 4):
                    bad = policy != "dense" and k == 2
                    yield (jnp.full_like(noise, jnp.nan) if bad
                           else noise + k), None

            return gen_fn

        eng = DiffusionEngine(sampler_factory=factory, latent_shape=(2,),
                              max_batch=1, max_wait_s=0.01,
                              guardrail=True)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), stream_every=1))
        chunks = list(eng.stream(0, timeout=30))
        r = eng.result(0, timeout=30)
        eng.stop()
        assert r.degraded is True
        assert np.all(np.isfinite(r.latents))
        # exactly one copy of each of the 3 chunks, every one finite:
        # chunk 1 came from the tripped attempt (delivered before the
        # NaN), chunks 2-3 from the dense re-serve
        assert len(chunks) == 3
        assert all(np.all(np.isfinite(c)) for c in chunks)
        np.testing.assert_allclose(chunks[-1], r.latents)
        assert eng.metrics()["dense_fallbacks"] == 1
