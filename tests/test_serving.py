"""Continuous-batching engine tests: per-request RNG threading, mixed
(resolution, steps) traffic from concurrent submitters, bucket purity,
the compiled-sampler LRU, and clean drain on stop()."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import DiffusionEngine, GenRequest


def _txt(val, tokens=1, dim=1):
    return np.full((tokens, dim), float(val), np.float32)


class TestPerRequestRNG:
    def test_seeds_differ_within_one_batch(self):
        """Regression for the seed bug: sample_fn used to receive
        rngs[0], collapsing every request's sampler randomness onto the
        first request's key.  A sampler that depends ONLY on the rng
        argument must now produce different latents for different seeds
        served in the same batch."""
        batches = []

        def sample_fn(noise, txt, rngs):
            batches.append(noise.shape[0])
            assert rngs.shape == (noise.shape[0], 2)  # full key batch
            return jax.vmap(
                lambda k: jax.random.normal(k, noise.shape[1:]))(rngs)

        eng = DiffusionEngine(sample_fn, latent_shape=(4,), max_batch=4,
                              max_wait_s=0.5)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), seed=0))
        eng.submit(GenRequest(request_id=1, txt=_txt(0), seed=1))
        r0 = eng.result(0, timeout=30)
        r1 = eng.result(1, timeout=30)
        eng.stop()
        assert 2 in batches  # both requests really shared one batch
        assert not np.allclose(r0.latents, r1.latents)

    def test_seed_determinism_across_batches(self):
        def sample_fn(noise, txt, rngs):
            return jax.vmap(
                lambda k: jax.random.normal(k, noise.shape[1:]))(rngs)

        eng = DiffusionEngine(sample_fn, latent_shape=(4,), max_batch=1,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0), seed=7))
        a = eng.result(0, timeout=30).latents
        eng.submit(GenRequest(request_id=1, txt=_txt(0), seed=7))
        b = eng.result(1, timeout=30).latents
        eng.stop()
        np.testing.assert_array_equal(a, b)


class TestMixedTrafficConcurrency:
    BUCKETS = (((2, 2, 1), 2), ((4, 4, 1), 3), ((2, 2, 1), 3))

    def test_threads_mixed_shapes_all_complete(self):
        """Multiple submitter threads, heterogeneous (resolution, steps)
        traffic: every request completes, results map back to the right
        request_id, and no sampler invocation ever mixes shapes."""
        served = []

        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                # bucket purity: the whole batch matches this bucket
                assert noise.shape[1:] == latent_shape
                assert txt.shape[0] == noise.shape[0] == rngs.shape[0]
                served.append((latent_shape, steps, noise.shape[0]))
                # encode (request marker, steps) into the output
                return (jnp.zeros_like(noise)
                        + txt[:, 0, 0].reshape((-1,) + (1,) * (noise.ndim - 1))
                        + 1000.0 * steps)
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.02)
        eng.start()
        n_threads, per_thread = 4, 8
        expected = {}

        def submit(tid):
            rng = np.random.default_rng(tid)
            for j in range(per_thread):
                rid = tid * 100 + j
                shape, steps = self.BUCKETS[rng.integers(len(self.BUCKETS))]
                expected[rid] = (shape, steps)
                eng.submit(GenRequest(request_id=rid, txt=_txt(rid),
                                      steps=steps, seed=rid,
                                      latent_shape=shape))
                time.sleep(0.001 * int(rng.integers(3)))

        threads = [threading.Thread(target=submit, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        for rid, (shape, steps) in expected.items():
            r = eng.result(rid, timeout=60)
            assert r.latents.shape == shape
            np.testing.assert_allclose(r.latents,
                                       float(rid) + 1000.0 * steps)
        eng.stop()
        assert sum(b for _, _, b in served) == n_threads * per_thread
        # every served batch drew from exactly one bucket (asserted in
        # fn); batching actually happened under concurrent submission
        assert len(served) <= n_threads * per_thread

    def test_hottest_bucket_drains_first(self):
        order = []

        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                order.append((latent_shape, noise.shape[0]))
                return noise
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=8,
                              max_wait_s=0.05)
        # queue before starting: 1 request cold bucket, 3 hot bucket
        eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=2,
                              latent_shape=(2, 2)))
        for i in range(1, 4):
            eng.submit(GenRequest(request_id=i, txt=_txt(i), steps=2,
                                  latent_shape=(4, 4)))
        eng.start()
        for i in range(4):
            eng.result(i, timeout=30)
        eng.stop()
        assert order[0] == ((4, 4), 3)  # deepest queue served first

    def test_cold_bucket_not_starved_by_hot_traffic(self):
        """Aging guard: a lone request in a cold bucket is served within
        ~starve_after_s even while fresh hot-bucket traffic keeps that
        bucket deeper the whole time (pure hottest-first would starve the
        cold request until the hot stream dries up)."""
        def factory(latent_shape, steps):
            def fn(noise, txt, rngs):
                time.sleep(0.02)
                return noise
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=4,
                              max_wait_s=0.01, starve_after_s=0.2)
        eng.start()
        # warm both shapes so first-call tracing doesn't skew timing
        eng.submit(GenRequest(request_id=9000, txt=_txt(0), steps=2,
                              latent_shape=(4, 4)))
        eng.submit(GenRequest(request_id=9001, txt=_txt(0), steps=2,
                              latent_shape=(2, 2)))
        eng.result(9000, timeout=30)
        eng.result(9001, timeout=30)

        stop_feed = threading.Event()

        def feeder():  # keep the hot bucket continuously refilled
            rid = 1
            while not stop_feed.is_set():
                if eng.pending() < 8:
                    for _ in range(4):
                        eng.submit(GenRequest(request_id=rid, txt=_txt(rid),
                                              steps=2, latent_shape=(4, 4)))
                        rid += 1
                time.sleep(0.005)

        t = threading.Thread(target=feeder)
        t.start()
        try:
            time.sleep(0.1)  # hot traffic flowing
            eng.submit(GenRequest(request_id=0, txt=_txt(0), steps=2,
                                  latent_shape=(2, 2)))
            r = eng.result(0, timeout=3.0)  # << the feeder's lifetime
            assert r.latents.shape == (2, 2)
        finally:
            stop_feed.set()
            t.join()
            eng.stop(drain=False)

    def test_compiled_sampler_lru_bounded_keeps_hottest(self):
        builds = []

        def factory(latent_shape, steps):
            builds.append((latent_shape, steps))
            return lambda noise, txt, rngs: noise

        eng = DiffusionEngine(sampler_factory=factory, max_batch=2,
                              max_wait_s=0.01, max_compiled=2)
        eng.start()
        rid = 0
        # bucket keys carry the policy name, reuse cadence, and the
        # dispatch mesh's seq-shard degree (1 = no ring)
        hot = ((2, 2), 2, None, None, 1)
        for round_ in range(3):
            for shape, steps in ((hot[0], hot[1]), ((4, 4), 2), ((8, 8), 2)):
                eng.submit(GenRequest(request_id=rid, txt=_txt(rid),
                                      steps=steps, latent_shape=shape))
                eng.result(rid, timeout=30)
                rid += 1
            # the hot bucket is touched again right away each round
            eng.submit(GenRequest(request_id=rid, txt=_txt(rid), steps=2,
                                  latent_shape=(2, 2)))
            eng.result(rid, timeout=30)
            rid += 1
            assert len(eng._compiled) <= 2
            assert hot in eng._compiled  # hottest entry survives eviction
        eng.stop()
        assert len(builds) > 3  # eviction forced rebuilds of cold buckets


class TestStopSemantics:
    def test_stop_drains_cleanly(self):
        """stop() serves everything already queued before joining — no
        result is orphaned under the lock."""
        def sample_fn(noise, txt, rngs):
            time.sleep(0.02)
            return noise

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=2,
                              max_wait_s=0.01)
        eng.start()
        for i in range(6):
            eng.submit(GenRequest(request_id=i, txt=_txt(i), seed=i))
        eng.stop()  # backlog still queued at this point
        assert eng.pending() == 0
        for i in range(6):
            r = eng.result(i, timeout=1.0)  # already resolved, no wait
            assert r.latents.shape == (2,)

    def test_submit_after_stop_raises(self):
        eng = DiffusionEngine(lambda n, t, r: n, latent_shape=(2,))
        eng.start()
        eng.stop()
        with pytest.raises(RuntimeError):
            eng.submit(GenRequest(request_id=0, txt=_txt(0)))

    def test_failed_batch_reports_error_not_hang(self):
        def sample_fn(noise, txt, rngs):
            raise ValueError("boom")

        eng = DiffusionEngine(sample_fn, latent_shape=(2,), max_batch=2,
                              max_wait_s=0.01)
        eng.start()
        eng.submit(GenRequest(request_id=0, txt=_txt(0)))
        with pytest.raises(RuntimeError, match="boom"):
            eng.result(0, timeout=30)
        eng.stop()
