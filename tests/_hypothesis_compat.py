"""Fallback when `hypothesis` is not installed: property tests degrade
to fixed-example tests.

``st.floats(lo, hi)`` / ``st.integers(lo, hi)`` become three fixed
examples (lo, midpoint, hi) and ``@given`` runs the test body once per
combination.  This keeps the suite collectible and the properties
spot-checked on bare environments; install ``hypothesis`` for real
randomized search.
"""

from __future__ import annotations

import functools
import inspect
import itertools


class _Strategy:
    def __init__(self, examples):
        self.examples = examples


class st:  # mirrors `hypothesis.strategies`
    @staticmethod
    def floats(lo, hi):
        return _Strategy([lo, (lo + hi) / 2.0, hi])

    @staticmethod
    def integers(lo, hi):
        return _Strategy([lo, (lo + hi) // 2, hi])


def settings(**_kw):
    def deco(fn):
        return fn
    return deco


def given(**strategies):
    names = sorted(strategies)
    combos = list(itertools.product(*(strategies[n].examples for n in names)))

    def deco(fn):
        @functools.wraps(fn)
        def run(*args, **kw):
            for combo in combos:
                fn(*args, **dict(zip(names, combo)), **kw)

        # Hide the strategy parameters from pytest's fixture resolution
        # (functools.wraps exposes the original signature otherwise).
        sig = inspect.signature(fn)
        params = [p for n, p in sig.parameters.items() if n not in names]
        run.__signature__ = sig.replace(parameters=params)
        del run.__wrapped__
        return run

    return deco
