"""Multi-device dispatch tier (DESIGN.md §10): the shard_map ripple /
reuse-mask path must be **bitwise-equal** to the single-device path for
the vdit_paper smoke grid across 1/2/8-way meshes, and indivisible
shapes must fall back to replicated execution rather than erroring.

Mesh-parametrized tests skip when the backend has too few devices (the
CI multi-device job runs them under the forced 8-virtual-device CPU
backend); the subprocess tests at the bottom guarantee the 8-way parity
checks — batch/head meshes and the context-parallel ring meshes
(DESIGN.md §14, in-process tier in tests/test_ring_attention.py) —
execute on every run of the suite regardless of the parent process's
device count.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import require_devices
from repro.config.base import RippleConfig
from repro.configs import get_smoke_config
from repro.core import dispatch
from repro.core.dispatch import (attention_dispatch, dispatch_mesh,
                                 resolve_plan)

# The vdit_paper smoke grid: frames=16 / t_vae=4 -> t=4; 64px / 8 / 2 -> 4.
ARCH = get_smoke_config("vdit-paper")
GRID = ARCH.model.grid(img_res=64)
N = GRID[0] * GRID[1] * GRID[2]
D = ARCH.model.d_model // ARCH.model.num_heads

CFG = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                   i_min=2, i_max=6)


def _qkv(seed=0, shape=(8, 2, N, D)):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, shape) for k in ks)


def _dispatch(q, k, v, backend=None, cfg=CFG):
    return attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                              step=jnp.asarray(5), total_steps=10,
                              backend=backend)


class TestShardedParity:
    @pytest.mark.parametrize("ways", [1, 2, 8])
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_bitwise_equal_to_single_device(self, ways, backend):
        require_devices(ways)
        q, k, v = _qkv()
        dispatch.clear_plan_cache()
        ref = np.asarray(_dispatch(q, k, v, backend))
        mesh = jax.make_mesh((ways, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, CFG, backend=backend)
            assert plan.batch_shards == ways
            out = np.asarray(_dispatch(q, k, v, backend))
        np.testing.assert_array_equal(out, ref)

    def test_head_sharding_bitwise_equal(self):
        require_devices(2)
        q, k, v = _qkv(1)
        dispatch.clear_plan_cache()
        ref = np.asarray(_dispatch(q, k, v))
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, CFG)
            assert (plan.head_axis, plan.head_shards) == ("model", 2)
            out = np.asarray(_dispatch(q, k, v))
        np.testing.assert_array_equal(out, ref)

    def test_sharded_under_jit(self):
        require_devices(2)
        q, k, v = _qkv(2)
        dispatch.clear_plan_cache()
        ref = np.asarray(_dispatch(q, k, v))
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            out = np.asarray(jax.jit(_dispatch)(q, k, v))
        np.testing.assert_array_equal(out, ref)

    def test_fused_mask_computed_per_shard(self):
        """fused_mask='on' (the reuse-mask kernel) under shard_map
        matches the host-mask single-device output bit for bit."""
        require_devices(2)
        import dataclasses
        cfg = dataclasses.replace(CFG, fused_mask="on")
        q, k, v = _qkv(3)
        dispatch.clear_plan_cache()
        ref = np.asarray(_dispatch(q, k, v, cfg=CFG))
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            out = np.asarray(_dispatch(q, k, v, cfg=cfg))
        np.testing.assert_array_equal(out, ref)


class TestPolicyParity:
    """Every registered reuse policy's ReuseDecision must keep dispatch
    bitwise-stable under sharding: the policy contract (DESIGN.md §11)
    says decisions look only along t/x/y, so each shard's decision is
    self-contained and shard_map output equals the single-device path
    bit for bit — for ripple, svg, equal_mse, dense and anything
    registered out-of-tree alike."""

    @pytest.mark.parametrize("ways", [1, 2, 8])
    @pytest.mark.parametrize("policy", sorted(dispatch.list_policies()))
    def test_bitwise_equal_to_single_device(self, ways, policy):
        require_devices(ways)
        q, k, v = _qkv(5)
        dispatch.clear_plan_cache()
        ref = np.asarray(attention_dispatch(
            q, k, v, grid=GRID, cfg=CFG, step=jnp.asarray(5),
            total_steps=10, policy=policy))
        mesh = jax.make_mesh((ways, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, CFG, policy=policy)
            assert plan.policy == policy
            if plan.backend != "dense":
                assert plan.batch_shards == ways
            out = np.asarray(attention_dispatch(
                q, k, v, grid=GRID, cfg=CFG, step=jnp.asarray(5),
                total_steps=10, policy=policy))
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("policy", sorted(dispatch.list_policies()))
    def test_head_sharded_bitwise_equal(self, policy):
        require_devices(2)
        q, k, v = _qkv(6)
        dispatch.clear_plan_cache()
        ref = np.asarray(attention_dispatch(
            q, k, v, grid=GRID, cfg=CFG, step=jnp.asarray(5),
            total_steps=10, policy=policy))
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            out = np.asarray(attention_dispatch(
                q, k, v, grid=GRID, cfg=CFG, step=jnp.asarray(5),
                total_steps=10, policy=policy))
        np.testing.assert_array_equal(out, ref)


class TestSparseShardedParity:
    """The block-sparse backend (DESIGN.md §12) under shard_map: every
    policy that emits a block map — svg, and ripple with the svg_mask
    combo — must stay bitwise-equal to the single-device path across
    1/2/8-way meshes.  Block maps are per-(batch, head) and derive only
    from t/x/y structure, so each shard's map is self-contained."""

    @staticmethod
    def _cases():
        import dataclasses
        return [("svg", CFG),
                ("ripple", dataclasses.replace(CFG, svg_mask=True))]

    @pytest.mark.parametrize("ways", [1, 2, 8])
    @pytest.mark.parametrize("case", range(2))
    def test_bitwise_equal_to_single_device(self, ways, case):
        require_devices(ways)
        policy, cfg = self._cases()[case]
        q, k, v = _qkv(7)
        dispatch.clear_plan_cache()
        ref = np.asarray(attention_dispatch(
            q, k, v, grid=GRID, cfg=cfg, step=jnp.asarray(5),
            total_steps=10, policy=policy))
        mesh = jax.make_mesh((ways, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, cfg, policy=policy)
            assert plan.backend == "sparse"
            assert plan.batch_shards == ways
            out = np.asarray(attention_dispatch(
                q, k, v, grid=GRID, cfg=cfg, step=jnp.asarray(5),
                total_steps=10, policy=policy))
        np.testing.assert_array_equal(out, ref)

    @pytest.mark.parametrize("case", range(2))
    def test_head_sharded_bitwise_equal(self, case):
        require_devices(2)
        policy, cfg = self._cases()[case]
        q, k, v = _qkv(8)
        dispatch.clear_plan_cache()
        ref = np.asarray(attention_dispatch(
            q, k, v, grid=GRID, cfg=cfg, step=jnp.asarray(5),
            total_steps=10, policy=policy))
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, cfg, policy=policy)
            assert plan.backend == "sparse"
            assert (plan.head_axis, plan.head_shards) == ("model", 2)
            out = np.asarray(attention_dispatch(
                q, k, v, grid=GRID, cfg=cfg, step=jnp.asarray(5),
                total_steps=10, policy=policy))
        np.testing.assert_array_equal(out, ref)


class TestFallbacks:
    def test_indivisible_batch_replicates(self):
        require_devices(2)
        q, k, v = _qkv(4, shape=(3, 2, N, D))  # B=3 on a 2-way mesh
        dispatch.clear_plan_cache()
        ref = np.asarray(_dispatch(q, k, v))
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, CFG)
            assert not plan.sharded
            out = np.asarray(_dispatch(q, k, v))
        np.testing.assert_array_equal(out, ref)

    def test_dense_backend_never_shards(self):
        require_devices(2)
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        with dispatch_mesh(mesh):
            dispatch.clear_plan_cache()
            plan = resolve_plan((8, 2, N, D), (8, 2, N, D), RippleConfig())
            assert plan.backend == "dense" and not plan.sharded

    def test_no_mesh_plan_is_unsharded(self):
        dispatch.clear_plan_cache()
        plan = resolve_plan((8, 2, N, D), (8, 2, N, D), CFG)
        assert not plan.sharded and plan.batch_axes == ()


def test_forced_8_device_parity_subprocess(multidevice_env):
    """Always-on guarantee (even when the parent runs single-device):
    under a forced 8-virtual-device CPU backend, shard_map output for the
    vdit_paper smoke grid is bitwise-equal to the single-device path on
    1/2/8-way batch meshes and a 4x2 batch-and-heads mesh — for every
    registered reuse policy."""
    code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config.base import RippleConfig
        from repro.core import dispatch
        from repro.core.dispatch import attention_dispatch, dispatch_mesh

        GRID, N, D = {tuple(GRID)!r}, {N}, 16
        cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                           i_min=2, i_max=6)
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (8, 2, N, D)) for kk in ks)
        import dataclasses
        combo = dataclasses.replace(cfg, svg_mask=True)
        cases = [(pol, cfg) for pol in dispatch.list_policies()]
        cases.append(("ripple", combo))  # svg_mask combo: sparse backend
        for pol, c in cases:
            run = lambda: np.asarray(attention_dispatch(
                q, k, v, grid=GRID, cfg=c, step=jnp.asarray(5),
                total_steps=10, policy=pol))
            dispatch.clear_plan_cache()
            ref = run()
            for shape in ((1, 1), (2, 1), (8, 1), (4, 2)):
                mesh = jax.make_mesh(shape, ("data", "model"))
                with dispatch_mesh(mesh):
                    dispatch.clear_plan_cache()
                    np.testing.assert_array_equal(run(), ref)
        print("sharded parity OK on", len(jax.devices()), "devices",
              "policies", list(dispatch.list_policies()))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=multidevice_env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "sharded parity OK on 8 devices" in r.stdout


def test_forced_8_device_ring_subprocess(multidevice_env):
    """Always-on context-parallel tier (DESIGN.md §14): under a forced
    8-virtual-device CPU backend, the ring path on a 2x2x2 (batch,
    heads, seq) mesh and a pure 1x1x8 seq mesh must (a) match the
    single-device dispatch — bitwise for the snap policies, within the
    documented svg tolerance — (b) elide ring hops for svg, and (c)
    replay the per-shard cache leaves bitwise across a refresh."""
    code = textwrap.dedent("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.config.base import RippleConfig
        from repro.core import decision_cache as dc
        from repro.core import dispatch
        from repro.core.dispatch import (attention_dispatch, dispatch_mesh,
                                         resolve_plan)

        cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                           i_min=2, i_max=6)

        def qkv(seed, n):
            ks = jax.random.split(jax.random.PRNGKey(seed), 3)
            return tuple(jax.random.normal(k, (2, 2, n, 16)) for k in ks)

        def run(q, k, v, grid, pol, be, c):
            return np.asarray(attention_dispatch(
                q, k, v, grid=grid, cfg=c, step=jnp.asarray(5),
                total_steps=10, policy=pol, backend=be))

        for mesh_shape, grid in (((2, 2, 2), (4, 8, 8)),
                                 ((1, 1, 8), (8, 8, 8))):
            n = grid[0] * grid[1] * grid[2]
            S = mesh_shape[2]
            mesh = jax.make_mesh(mesh_shape, ("data", "model", "seq"))
            for pol, be, tol in (("ripple", "reference", 0.0),
                                 ("equal_mse", "reference", 0.0),
                                 ("svg", None, 2e-5)):
                q, k, v = qkv(1, n)
                dispatch.clear_plan_cache()
                ref = run(q, k, v, grid, pol, be, cfg)
                with dispatch_mesh(mesh):
                    dispatch.clear_plan_cache()
                    plan = resolve_plan(q.shape, v.shape, cfg, backend=be,
                                        policy=pol, grid=grid)
                    assert plan.seq_shards == S, (mesh_shape, pol,
                                                  plan.summary())
                    out = run(q, k, v, grid, pol, be, cfg)
                if tol:
                    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)
                else:
                    np.testing.assert_array_equal(out, ref)

        # svg ring telemetry + cache replay on the pure seq mesh
        grid, S = (8, 8, 8), 8
        n = 512
        c2 = dataclasses.replace(cfg, reuse_every=2)
        q, k, v = qkv(2, n)
        mesh = jax.make_mesh((1, 1, S), ("data", "model", "seq"))
        outs, caches = {}, {}
        with dispatch_mesh(mesh):
            for every in (2, 1):
                c = dataclasses.replace(cfg, reuse_every=every)
                dispatch.clear_plan_cache()
                state = dc.initial_state(q.shape, grid=grid, cfg=c,
                                         policy="svg", backend="sparse")
                outs[every], caches[every] = [], []
                for s in range(3):
                    out, state = attention_dispatch(
                        q, k, v, grid=grid, cfg=c, step=jnp.asarray(s),
                        total_steps=8, policy="svg",
                        cached_decision=state, return_decision=True)
                    outs[every].append(np.asarray(out))
                    caches[every].append(np.asarray(state.bias))
        elided = np.asarray(state.elided)
        assert elided.shape == (S,) and elided.sum() > 0, elided
        # step 1 is a hit at cadence 2, a refresh at cadence 1 — with
        # identical inputs the outputs and the per-shard bias leaves
        # must replay bitwise, across the step-2 refresh too
        for s in range(3):
            np.testing.assert_array_equal(outs[2][s], outs[1][s])
            np.testing.assert_array_equal(caches[2][s], caches[1][s])
        print("ring parity OK on", len(jax.devices()), "devices;",
              "elided", elided.tolist())
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=multidevice_env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "ring parity OK on 8 devices" in r.stdout
