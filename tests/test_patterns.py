"""Pattern-search subsystem tests (DESIGN.md §16): template rendering
properties, the versioned per-(layer, head) artifact (round-trip,
corrupt-file and schema-mismatch recovery — for both the pattern
loader and the hardened autotune cache loader), the static plan-once
policy (bitwise parity with the manually-driven sparse backend, single
cache refresh per trajectory), the rainfusion tri-branch routing, the
offline search's static/dynamic classification, and spatial-only
patterns on T=1 image grids."""

import dataclasses
import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.config.base import RippleConfig
from repro.core import dispatch, patterns
from repro.core.decision_cache import initial_state, supports_cache
from repro.core.dispatch import attention_dispatch
from repro.core.policy import get_policy, list_policies
from repro.kernels.sparse.ops import (PARTIAL, SKIP, block_map_from_keep,
                                      sparse_attention_pallas,
                                      sparse_block_stats)

GRIDS = [(1, 4, 4), (2, 4, 4), (1, 8, 8), (4, 8, 8), (3, 5, 7)]


def _qkv(seed, shape):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, shape) for k in ks)


def _toy_artifact(grid=(4, 8, 8), block=(32, 32)):
    """Hand-built artifact: head 0 static temporal, head 1 static
    spatial, head 2 dynamic."""
    t_spec = patterns.template("frame_diag", window=1, sink=1)
    s_spec = patterns.template("spatial_local", radius=1)
    heads = {
        (0, 0): patterns.HeadAssignment(
            spec=t_spec, static=True, branch="spatial", psnr_db=40.0,
            skip_rate=patterns.template_skip_rate(t_spec, grid, block),
            stability=1.0),
        (0, 1): patterns.HeadAssignment(
            spec=s_spec, static=True, branch="spatial", psnr_db=35.0,
            skip_rate=patterns.template_skip_rate(s_spec, grid, block),
            stability=1.0),
        (0, 2): patterns.HeadAssignment(
            spec=patterns.template("dense"), static=False,
            branch="dynamic", psnr_db=0.0, skip_rate=0.0, stability=0.4),
    }
    return patterns.PatternArtifact(grid=grid, block_shape=block,
                                    tolerance_db=30.0, heads=heads)


class TestTemplateProperties:
    """Satellite: every template renders a valid block map across
    grids and block shapes (fixed examples without hypothesis)."""

    @settings(deadline=None, max_examples=30)
    @given(gi=st.integers(0, len(GRIDS) - 1), bq=st.integers(1, 48),
           bk=st.integers(1, 48))
    def test_bank_renders_valid_maps(self, gi, bq, bk):
        grid = GRIDS[gi]
        n = grid[0] * grid[1] * grid[2]
        for spec in patterns.default_bank(grid):
            keep = patterns.render_keep(spec, grid)
            assert keep.shape == (n, n)
            assert keep.dtype == np.bool_
            # no template may mask a token's own key
            assert keep.diagonal().all()

            bm = patterns.block_map_np(keep, bq, bk)
            cq, ck = min(bq, n), min(bk, n)
            assert bm.shape == (-(-n // cq), -(-n // ck))
            assert bm.dtype == np.int32
            # the kept diagonal means no q-row of tiles is all-SKIP
            assert (bm != SKIP).any(axis=-1).all()
            # tile states consistent with the mask, via parity with the
            # kernel's own tiling (edge padding included)
            jm = np.asarray(block_map_from_keep(jnp.asarray(keep), bq, bk))
            np.testing.assert_array_equal(bm, jm)

    def test_dense_template_is_all_full(self):
        bm = patterns.render_block_map(patterns.template("dense"),
                                       (2, 4, 4), (16, 16))
        assert (bm == 1).all()  # FULL everywhere, zero skip
        assert patterns.template_skip_rate(
            patterns.template("dense"), (2, 4, 4), (16, 16)) == 0.0

    def test_unknown_family_raises(self):
        with pytest.raises(ValueError, match="unknown template family"):
            patterns.template("nope")

    def test_image_grid_spatial_pattern_beats_dense_on_skip(self):
        # satellite: T=1 grids (dit_xl2 / unet_sd15 style) must realize
        # tile skips from the spatial-only default template
        for grid in ((1, 16, 16), (1, 32, 32)):
            spec = patterns.default_template(grid)
            assert spec.family == "spatial_local"
            skip = patterns.template_skip_rate(spec, grid, (32, 32))
            assert skip > 0.0  # dense's is identically 0


class TestArtifact:
    def test_round_trip_preserves_version(self, tmp_path):
        art = _toy_artifact()
        path = str(tmp_path / "patterns.json")
        patterns.save_pattern_artifact(art, path)
        back = patterns.load_pattern_artifact(path)
        assert back is not None
        assert back.version == art.version
        assert back.heads == art.heads
        assert back.grid == art.grid

    def test_assignment_and_keep_routing(self):
        art = _toy_artifact()
        assert art.assignment(0, 0).static
        assert art.assignment(0, 2) is None  # dynamic -> no static spec
        keep = art.keep_for(art.grid, 3)
        n = int(np.prod(art.grid))
        assert keep.shape == (3, n, n)
        assert keep[2].all()  # dynamic head: unmasked
        assert not keep[0].all()
        assert tuple(art.branches(3)) == ("spatial", "spatial", "dynamic")

    def test_corrupt_bytes_warn_and_none(self, tmp_path):
        path = tmp_path / "patterns.json"
        path.write_bytes(b"\x00{garbage not json")
        with pytest.warns(RuntimeWarning, match="pattern artifact"):
            assert patterns.load_pattern_artifact(str(path)) is None

    def test_schema_mismatch_warns_and_none(self, tmp_path):
        path = tmp_path / "patterns.json"
        path.write_text(json.dumps({"schema": "repro-pattern/999",
                                    "grid": [2, 4, 4], "heads": {}}))
        with pytest.warns(RuntimeWarning, match="pattern artifact"):
            assert patterns.load_pattern_artifact(str(path)) is None

    def test_missing_file_is_quietly_none(self, tmp_path):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            assert patterns.load_pattern_artifact(
                str(tmp_path / "absent.json")) is None

    def test_install_artifact_raises_on_corrupt(self, tmp_path):
        path = tmp_path / "bad.json"
        path.write_text("{]")
        with pytest.raises(ValueError, match="no usable pattern artifact"), \
                warnings.catch_warnings():
            warnings.simplefilter("ignore")
            patterns.install_artifact(str(path))

    def test_env_var_paths_artifact(self, tmp_path, monkeypatch):
        path = str(tmp_path / "env_patterns.json")
        monkeypatch.setenv("REPRO_PATTERN_ARTIFACT", path)
        assert patterns.pattern_artifact_path() == path
        patterns.save_pattern_artifact(_toy_artifact())
        assert json.load(open(path))["schema"] == patterns.PATTERN_SCHEMA


class TestAutotuneCacheHardening:
    """Satellite: the autotune disk cache warns and regenerates on
    garbage bytes or a version-mismatched schema instead of crashing."""

    def _reset(self):
        dispatch.clear_plan_cache()

    def test_garbage_bytes_warn_and_empty(self, tmp_path, monkeypatch):
        path = tmp_path / "autotune.json"
        path.write_bytes(b"\x93\xffnot json at all")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        self._reset()
        try:
            with pytest.warns(RuntimeWarning, match="corrupt"):
                assert dispatch._load_disk_cache() == {}
        finally:
            self._reset()

    def test_schema_mismatch_warns_and_empty(self, tmp_path, monkeypatch):
        path = tmp_path / "autotune.json"
        path.write_text(json.dumps({"__schema__": "repro-autotune/999",
                                    "cpu:pallas:n64:d8:dv8":
                                        {"block_q": 16, "block_k": 16}}))
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        self._reset()
        try:
            with pytest.warns(RuntimeWarning, match="schema"):
                assert dispatch._load_disk_cache() == {}
        finally:
            self._reset()

    def test_malformed_entries_dropped(self, tmp_path, monkeypatch):
        path = tmp_path / "autotune.json"
        good = {"block_q": 16, "block_k": 16, "us": 1.0}
        path.write_text(json.dumps({"__schema__": dispatch._AUTOTUNE_SCHEMA,
                                    "k_good": good, "k_bad": {"什么": 1},
                                    "k_str": "nope"}))
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        self._reset()
        try:
            with pytest.warns(RuntimeWarning, match="malformed"):
                cache = dispatch._load_disk_cache()
            assert cache == {"k_good": good}
        finally:
            self._reset()

    def test_regenerates_with_schema_marker(self, tmp_path, monkeypatch):
        path = tmp_path / "autotune.json"
        path.write_bytes(b"truncated{")
        monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
        self._reset()
        try:
            q, k, v = _qkv(0, (1, 1, 64, 8))
            with pytest.warns(RuntimeWarning, match="corrupt"):
                dispatch.autotune_attention(
                    q, k, v, candidates=((16, 16), (32, 32)), repeats=1)
            disk = json.load(open(path))
            assert disk["__schema__"] == dispatch._AUTOTUNE_SCHEMA
            assert any(k != "__schema__" for k in disk)
        finally:
            self._reset()


class TestStaticPolicy:
    GRID = (4, 8, 8)
    N = 256

    def test_registered(self):
        assert {"static", "rainfusion"} <= set(list_policies())
        assert getattr(get_policy("static"), "plan_once", False)

    def test_dispatch_matches_manual_sparse_bitwise(self):
        """Satellite: static-policy dispatch is bitwise identical to the
        same constant block map fed manually through the sparse
        backend."""
        q, k, v = _qkv(3, (1, 2, self.N, 16))
        cfg = RippleConfig(enabled=True, policy="static")
        dispatch.clear_plan_cache()
        try:
            with patterns.use_artifact(None):
                plan = dispatch.resolve_plan(q.shape, v.shape, cfg,
                                             backend="sparse",
                                             grid=self.GRID)
                out = attention_dispatch(q, k, v, grid=self.GRID, cfg=cfg,
                                         step=0, total_steps=2,
                                         backend="sparse")
                keep = patterns.pattern_keep(None, self.GRID, 2)
            bm = patterns.block_map_np(keep, plan.block_q, plan.block_k)
            bias = None
            if (bm == PARTIAL).any():
                bias = jnp.where(jnp.asarray(keep), 0.0,
                                 -jnp.inf).astype(jnp.float32)
            manual = sparse_attention_pallas(
                q, k, v, bias=bias, block_map=jnp.asarray(bm),
                block_q=plan.block_q, block_k=plan.block_k)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(manual))
        finally:
            dispatch.clear_plan_cache()

    def test_plan_once_single_refresh_and_stable_outputs(self):
        """plan_once: one refresh at step 0, hits ever after — even at a
        cadence that would re-decide — and bitwise-stable outputs."""
        steps = 6
        q, k, v = _qkv(5, (1, 2, self.N, 16))
        cfg = RippleConfig(enabled=True, policy="static", reuse_every=2)
        assert supports_cache(cfg)
        dispatch.clear_plan_cache()
        try:
            with patterns.use_artifact(None):
                @jax.jit
                def loop(q, k, v):
                    init = initial_state(q.shape, grid=self.GRID, cfg=cfg)

                    def body(carry, si):
                        out, carry = attention_dispatch(
                            q, k, v, grid=self.GRID, cfg=cfg, step=si,
                            total_steps=steps, cached_decision=carry)
                        return carry, out

                    return jax.lax.scan(body, init, jnp.arange(steps))

                final, outs = loop(q, k, v)
            # counters are per (batch, head): exactly one refresh (step
            # 0) and steps-1 hits for every head, despite reuse_every=2
            refreshes = np.asarray(final.refreshes)
            hits = np.asarray(final.hits)
            np.testing.assert_array_equal(
                refreshes, np.ones_like(refreshes))
            np.testing.assert_array_equal(
                hits, np.full_like(hits, steps - 1))
            outs = np.asarray(outs)
            for i in range(1, steps):
                np.testing.assert_array_equal(outs[0], outs[i])
        finally:
            dispatch.clear_plan_cache()

    def test_artifact_swap_changes_plan_token(self):
        pol = get_policy("static")
        art = _toy_artifact()
        with patterns.use_artifact(art):
            assert pol.plan_token(None) == art.version
        with patterns.use_artifact(None):
            assert pol.plan_token(None) is None

    def test_engine_bucket_key_carries_pattern_token(self):
        from repro.serving.engine import _pattern_token

        art = _toy_artifact()
        with patterns.use_artifact(art):
            assert _pattern_token("static") == art.version
            assert _pattern_token("dense") is None
            assert _pattern_token("unregistered") is None
        with patterns.use_artifact(None):
            assert _pattern_token("static") is None

    def test_savings_and_skip_are_structural(self):
        q, k, v = _qkv(7, (1, 2, self.N, 16))
        cfg = RippleConfig(enabled=True, policy="static")
        dispatch.clear_plan_cache()
        try:
            with patterns.use_artifact(None):
                out, stats = attention_dispatch(
                    q, k, v, grid=self.GRID, cfg=cfg, step=0,
                    total_steps=2, backend="sparse", with_stats=True)
            assert float(stats.savings) > 0.0
            assert float(stats.structural_savings) > 0.0
            assert float(stats.q_snap_frac) == 0.0  # no snapping, ever
        finally:
            dispatch.clear_plan_cache()


class TestRainFusion:
    GRID = (4, 8, 8)
    N = 256

    def test_tri_branch_decision(self):
        """Static heads get the constant mask + identity snap sources;
        the dynamic head keeps ripple's snap path."""
        art = _toy_artifact()
        pol = get_policy("rainfusion")
        q, k, _ = _qkv(11, (1, 3, self.N, 16))
        cfg = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                           i_min=1, i_max=4, policy="rainfusion")
        thetas = pol.thetas_for(cfg, jnp.asarray(2), 6)
        with patterns.use_artifact(art):
            dec = pol.decide(q, k, grid=self.GRID, cfg=cfg, thetas=thetas,
                             block_shape=(32, 32))
        assert dec.bias is not None
        assert dec.block_map is not None
        assert float(sparse_block_stats(dec.block_map)) > 0.0
        # static heads' operands are untouched by snapping
        np.testing.assert_array_equal(np.asarray(dec.q[:, 0]),
                                      np.asarray(q[:, 0]))
        np.testing.assert_array_equal(np.asarray(dec.q[:, 1]),
                                      np.asarray(q[:, 1]))
        if dec.q_mask is not None:
            assert not bool(np.asarray(dec.q_mask)[:, 0].any())
            assert not bool(np.asarray(dec.q_mask)[:, 1].any())

    def test_no_artifact_degrades_to_ripple(self):
        q, k, v = _qkv(13, (1, 2, self.N, 16))
        cfg_rf = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                              i_min=1, i_max=4, policy="rainfusion")
        cfg_rp = dataclasses.replace(cfg_rf, policy="ripple")
        dispatch.clear_plan_cache()
        try:
            with patterns.use_artifact(None):
                out_rf = attention_dispatch(q, k, v, grid=self.GRID,
                                            cfg=cfg_rf, step=2,
                                            total_steps=6,
                                            backend="reference")
            out_rp = attention_dispatch(q, k, v, grid=self.GRID,
                                        cfg=cfg_rp, step=2, total_steps=6,
                                        backend="reference")
            np.testing.assert_allclose(np.asarray(out_rf),
                                       np.asarray(out_rp), atol=1e-6)
        finally:
            dispatch.clear_plan_cache()

    def test_sweepable_end_to_end(self):
        """`--policy rainfusion` path: plain dispatch resolves a plan
        and runs with stats under the registered policy name."""
        q, k, v = _qkv(17, (1, 3, self.N, 16))
        cfg = RippleConfig(enabled=True, policy="rainfusion")
        dispatch.clear_plan_cache()
        try:
            with patterns.use_artifact(_toy_artifact()):
                out, stats = attention_dispatch(
                    q, k, v, grid=self.GRID, cfg=cfg, step=0,
                    total_steps=2, with_stats=True)
            assert out.shape == q.shape
            assert float(stats.savings) > 0.0
        finally:
            dispatch.clear_plan_cache()


class TestSearchClassification:
    def test_tri_branch_classification_smoke(self):
        """Temporally-correlated heads classify static/temporal-ish,
        unstructured heads stay dynamic (dense)."""
        from repro.launch.pattern_search import calibration_traffic

        grid = (4, 8, 8)
        samples = calibration_traffic(
            grid=grid, layers=1, heads=3, steps=2, prompts=1, d=16,
            characters=("temporal", "spatial", "dynamic"))
        art = patterns.search_patterns(samples, grid,
                                       block_shape=(32, 32),
                                       tolerance_db=20.0)
        a_t = art.heads[(0, 0)]  # temporal character
        a_s = art.heads[(0, 1)]  # spatial character
        a_d = art.heads[(0, 2)]  # dynamic character
        assert a_t.static and a_t.spec.family != "dense"
        assert a_s.static and a_s.spec.family != "dense"
        assert not a_d.static and a_d.spec.family == "dense"
        assert 0.0 < art.static_fraction() < 1.0

    def test_spatial_only_search_on_image_grid(self):
        """T=1 grid: the bank is spatial-only and a spatial head's
        winner realizes tile skips (beats dense)."""
        from repro.launch.pattern_search import calibration_traffic

        grid = (1, 16, 16)
        assert all(s.family in ("dense", "spatial_local", "global_sink")
                   for s in patterns.default_bank(grid))
        samples = calibration_traffic(grid=grid, layers=1, heads=1,
                                      steps=2, prompts=1, d=16,
                                      characters=("spatial",))
        art = patterns.search_patterns(samples, grid,
                                       block_shape=(32, 32),
                                       tolerance_db=20.0)
        a = art.heads[(0, 0)]
        assert a.static
        assert a.skip_rate > 0.0


class TestStaticOnRing:
    def test_static_matches_single_device_and_elides(self):
        """Constant maps on the 2-shard ring: same output, and the
        off-diagonal all-SKIP hop is elided shard-locally."""
        from conftest import require_devices
        from repro.core import decision_cache as dc
        from repro.launch.mesh import parse_mesh_spec

        require_devices(2)
        grid = (4, 8, 8)
        n = 256
        q, k, v = _qkv(23, (1, 2, n, 16))
        cfg = RippleConfig(enabled=True, policy="static", reuse_every=2)
        dispatch.clear_plan_cache()
        try:
            with patterns.use_artifact(None):
                ref = attention_dispatch(q, k, v, grid=grid, cfg=cfg,
                                         step=0, total_steps=2,
                                         backend="sparse")
                mesh = parse_mesh_spec("1x1x2")
                with dispatch.dispatch_mesh(mesh):
                    state = dc.initial_state(q.shape, grid=grid, cfg=cfg,
                                             policy="static",
                                             backend="sparse")
                    out = None
                    for s in range(2):
                        out, state = attention_dispatch(
                            q, k, v, grid=grid, cfg=cfg,
                            step=jnp.asarray(s), total_steps=2,
                            backend="sparse", cached_decision=state,
                            return_decision=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=3e-5)
            assert state.elided is not None
            assert int(np.asarray(state.elided).sum()) > 0
            # plan-once on the ring too: one refresh per shard
            assert int(np.asarray(state.refreshes).sum()) == \
                len(np.asarray(state.refreshes).ravel())
        finally:
            dispatch.clear_plan_cache()
