"""Reuse-policy registry tests (DESIGN.md §11): registry contract,
per-policy ReuseDecision semantics, dispatch equivalence for every
built-in, plan-cache keying on the policy name, and the out-of-tree
registration path end-to-end through the serving engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import RippleConfig
from repro.core import dispatch, policy as policy_lib
from repro.core.dispatch import attention_dispatch, dense_attention, \
    resolve_plan
from repro.core.policy import (DensePolicy, EqualMSEPolicy, ReuseDecision,
                               ReusePolicy, RipplePolicy, SVGPolicy,
                               get_policy, list_policies, register_policy)
from repro.core.reuse import compute_reuse
from repro.core.svg_mask import svg_block_mask

GRID = (4, 4, 6)
N = GRID[0] * GRID[1] * GRID[2]
D = 16

CFG = RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                   i_min=2, i_max=6)
STEP = jnp.asarray(5)


def _qkv(seed=0, shape=(2, 3, N, D)):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, shape) for k in ks)


def _dispatch(policy, cfg=CFG, seed=1, **kw):
    q, k, v = _qkv(seed)
    return attention_dispatch(q, k, v, grid=GRID, cfg=cfg, step=STEP,
                              total_steps=10, policy=policy, **kw)


class TestRegistry:
    def test_builtins_registered(self):
        assert {"ripple", "svg", "equal_mse", "dense"} <= set(list_policies())

    def test_get_policy_by_name_and_instance(self):
        pol = get_policy("ripple")
        assert isinstance(pol, RipplePolicy)
        assert get_policy(pol) is pol  # instances pass through

    def test_unknown_policy_raises_with_listing(self):
        with pytest.raises(KeyError, match="ripple"):
            get_policy("nope")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_policy(RipplePolicy())

    def test_override_and_custom_name(self):
        pol = RipplePolicy()
        try:
            register_policy(pol, name="ripple_test_tmp")
            assert get_policy("ripple_test_tmp") is pol
            pol2 = register_policy(RipplePolicy(), name="ripple_test_tmp",
                                   override=True)
            assert get_policy("ripple_test_tmp") is pol2
        finally:
            policy_lib._REGISTRY.pop("ripple_test_tmp", None)

    def test_nameless_policy_rejected(self):
        with pytest.raises(ValueError, match="name"):
            register_policy(ReusePolicy())


class TestBuiltinDecisions:
    """Each built-in's ReuseDecision honours the dataclass contract."""

    def test_ripple_decision_snaps_and_masks(self):
        q, k, _ = _qkv(2)
        pol = get_policy("ripple")
        thetas = pol.thetas_for(CFG, STEP, 10)
        d = pol.decide(q, k, grid=GRID, cfg=CFG, thetas=thetas)
        assert d.q.shape == q.shape and d.k.shape == k.shape
        assert d.q_mask.dtype == jnp.bool_ and d.q_mask.shape == q.shape
        assert 0.0 < float(d.savings) < 1.0
        assert d.active_axes == ("t", "x", "y")
        # snapped exactly where the host pipeline says
        r = compute_reuse(q, GRID, thetas)
        np.testing.assert_array_equal(np.asarray(d.q), np.asarray(r.snapped))

    def test_svg_decision_emits_bias_not_snaps(self):
        q, k, _ = _qkv(3)
        pol = get_policy("svg")
        assert pol.emits_bias and not pol.snaps_operands
        d = pol.decide(q, k, grid=GRID, cfg=CFG,
                       thetas=pol.thetas_for(CFG, STEP, 10))
        assert d.q is q and d.k is k  # operands untouched
        assert d.bias is not None and d.bias.shape[-2:] == (N, N)
        assert 0.0 < float(d.savings) < 1.0

    def test_svg_decision_block_map_consistent_with_mask(self):
        """Given a plan block_shape, svg tiles its keep-mask into the
        sparse backend's states; FULL tiles keep everything, SKIP tiles
        nothing (PARTIAL covers the rest)."""
        from repro.kernels.sparse.ops import FULL, SKIP
        from repro.kernels.sparse.ref import expand_block_map

        q, k, _ = _qkv(3)
        pol = get_policy("svg")
        d = pol.decide(q, k, grid=GRID, cfg=CFG,
                       thetas=pol.thetas_for(CFG, STEP, 10),
                       block_shape=(32, 32))
        assert d.block_map is not None
        keep = np.asarray(svg_block_mask(q, k, GRID))
        st = np.asarray(expand_block_map(d.block_map, N, N, 32, 32))
        assert keep[st == FULL].all()
        assert not keep[st == SKIP].any()
        # without a planned block_shape the decision carries no map
        d2 = pol.decide(q, k, grid=GRID, cfg=CFG,
                        thetas=pol.thetas_for(CFG, STEP, 10))
        assert d2.block_map is None

    def test_equal_mse_schedule_grows_with_step(self):
        pol = get_policy("equal_mse")
        th = [float(pol.thetas_for(CFG, jnp.asarray(i), 20)["t"])
              for i in range(20)]
        assert th[0] == 0.0 and th[19] == 0.0      # dense outside range
        active = th[CFG.i_min:19]
        assert all(b >= a for a, b in zip(active, active[1:]))
        assert active[0] >= CFG.theta_min - 1e-6
        assert max(active) <= CFG.theta_max + 1e-6

    def test_equal_mse_table_override(self):
        tbl = np.asarray([0.1, 0.2, 0.3], np.float32)
        pol = EqualMSEPolicy.from_schedule(tbl, i_min=2)
        assert float(pol.thetas_for(CFG, jnp.asarray(3), 10)["t"]) \
            == pytest.approx(0.2)
        # clamped to the table's last entry past its end
        assert float(pol.thetas_for(CFG, jnp.asarray(8), 10)["t"]) \
            == pytest.approx(0.3)

    def test_dense_policy_is_noop(self):
        pol = get_policy("dense")
        assert pol.is_dense
        q, k, _ = _qkv(4)
        d = pol.decide(q, k, grid=GRID, cfg=CFG, thetas={})
        assert d.q is q and d.k is k and d.bias is None
        assert float(d.savings) == 0.0

    def test_stats_contract(self):
        for name in list_policies():
            pol = get_policy(name)
            q, k, _ = _qkv(5)
            d = pol.decide(q, k, grid=GRID, cfg=CFG,
                           thetas=pol.thetas_for(CFG, STEP, 10))
            st = pol.stats(d)
            assert 0.0 <= float(st.savings) <= 1.0
            assert 0.0 <= float(st.q_snap_frac) <= 1.0


class TestDispatchWithPolicies:
    def test_ripple_is_the_default(self):
        out_default = _dispatch(policy=None)
        out_ripple = _dispatch(policy="ripple")
        np.testing.assert_array_equal(np.asarray(out_default),
                                      np.asarray(out_ripple))

    def test_dense_policy_equals_dense_attention(self):
        q, k, v = _qkv(1)
        out = _dispatch("dense")
        ref = dense_attention(q, k, v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)

    def test_svg_policy_equals_masked_dense(self):
        # auto now routes svg through the block-sparse kernel; its
        # online softmax matches the host masked softmax to fp tolerance
        q, k, v = _qkv(1)
        out = _dispatch("svg")
        keep = svg_block_mask(q, k, GRID)
        bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        ref = dense_attention(q, k, v, 1.0 / np.sqrt(D), bias)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    def test_cfg_policy_field_selects(self):
        cfg = dataclasses.replace(CFG, policy="dense")
        q, k, v = _qkv(1)
        out = attention_dispatch(q, k, v, grid=GRID, cfg=cfg, step=STEP,
                                 total_steps=10)
        ref = dense_attention(q, k, v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)

    def test_equal_mse_differs_from_ripple_midschedule(self):
        out_r = _dispatch("ripple")
        out_e = _dispatch("equal_mse")
        assert not np.array_equal(np.asarray(out_r), np.asarray(out_e))

    def test_policies_work_under_jit(self):
        q, k, v = _qkv(6)
        for name in list_policies():
            cfg = dataclasses.replace(CFG, policy=name)
            fn = jax.jit(lambda q, k, v, cfg=cfg: attention_dispatch(
                q, k, v, grid=GRID, cfg=cfg, step=STEP, total_steps=10))
            eager = attention_dispatch(q, k, v, grid=GRID, cfg=cfg,
                                       step=STEP, total_steps=10)
            np.testing.assert_allclose(np.asarray(fn(q, k, v)),
                                       np.asarray(eager), atol=1e-5)

    def test_with_stats_per_policy(self):
        for name in ("ripple", "svg", "equal_mse"):
            _, st = _dispatch(name, with_stats=True)
            assert 0.0 < float(st.savings) < 1.0
        _, st = _dispatch("dense", with_stats=True)
        assert float(st.savings) == 0.0

    def test_svg_structural_savings_realized_by_sparse_backend(self):
        """With the block-sparse backend honouring the mask, SVG's
        structural savings are the *actually skipped* tile fraction —
        positive once the grid spans several tiles, never echoing the
        raw mask density."""
        grid = (8, 8, 8)
        n = grid[0] * grid[1] * grid[2]
        ks = jax.random.split(jax.random.PRNGKey(9), 3)
        q, k, v = (jax.random.normal(kk, (2, 2, n, D)) for kk in ks)
        out, st = attention_dispatch(q, k, v, grid=grid, cfg=CFG, step=STEP,
                                     total_steps=10, policy="svg",
                                     with_stats=True)
        assert float(st.savings) > 0.0
        assert 0.0 < float(st.structural_savings) < 1.0
        # realized (tile-granular) savings never exceed the modeled
        # (score-granular) mask density
        assert float(st.structural_savings) <= float(st.savings) + 1e-6

    def test_ripple_svg_combo_structural_is_skipped_tile_fraction(self):
        """ripple+svg_mask also executes on the sparse backend; its
        realized savings must be the skipped-tile fraction of the block
        map it carried, not the collapse accounting (which never ran)."""
        from repro.kernels.sparse.ops import sparse_block_stats

        grid = (8, 8, 8)
        n = grid[0] * grid[1] * grid[2]
        cfg = dataclasses.replace(CFG, svg_mask=True)
        ks = jax.random.split(jax.random.PRNGKey(10), 3)
        q, k, v = (jax.random.normal(kk, (1, 2, n, D)) for kk in ks)
        _, st = attention_dispatch(q, k, v, grid=grid, cfg=cfg, step=STEP,
                                   total_steps=10, with_stats=True)
        pol = get_policy("ripple")
        d = pol.decide(q, k, grid=grid, cfg=cfg,
                       thetas=pol.thetas_for(cfg, STEP, 10),
                       block_shape=(128, 128))
        assert float(st.structural_savings) > 0.0
        assert float(st.structural_savings) == pytest.approx(
            float(sparse_block_stats(d.block_map)))

    def test_svg_structural_zero_off_the_sparse_backend(self):
        """Forced onto the dense reference path nothing is structurally
        skipped, so the realized metric must fall back to 0."""
        _, st = _dispatch("svg", with_stats=True, backend="reference")
        assert float(st.savings) > 0.0
        assert float(st.structural_savings) == 0.0


class TestPlanKeying:
    def test_plans_key_on_policy_name(self):
        dispatch.clear_plan_cache()
        try:
            shape = (1, 1, N, D)
            p_rip = resolve_plan(shape, shape, CFG, policy="ripple")
            p_svg = resolve_plan(shape, shape, CFG, policy="svg")
            p_dense = resolve_plan(shape, shape, CFG, policy="dense")
            assert p_rip is not p_svg
            assert (p_rip.policy, p_svg.policy, p_dense.policy) == \
                ("ripple", "svg", "dense")
            assert p_dense.backend == "dense"
            # same policy resolves to the same cached plan
            assert resolve_plan(shape, shape, CFG, policy="svg") is p_svg
        finally:
            dispatch.clear_plan_cache()

    def test_bias_policy_resolves_sparse_on_auto(self):
        """svg tiles its mask into a block map, so auto prefers the
        block-sparse backend (no reference downgrade) — and never the
        collapse path, whose window-constant-bias assumption the SVG
        mask violates."""
        dispatch.clear_plan_cache()
        try:
            cfg = dataclasses.replace(CFG, execution="collapse")
            shape = (1, 1, N, D)
            assert resolve_plan(shape, shape, cfg).backend == "collapse"
            assert resolve_plan(shape, shape, cfg,
                                policy="svg").backend == "sparse"
            # ... but an external caller bias (arbitrary, not tile-
            # structured) keeps svg off the sparse fast path
            assert resolve_plan(shape, shape, cfg, policy="svg",
                                has_bias=True).backend == "reference"
        finally:
            dispatch.clear_plan_cache()

    def test_explicit_biasless_backend_downgrades_for_bias_policy(self):
        """Forcing pallas/collapse with a bias-emitting policy must not
        crash inside a jitted sampler; the plan downgrades to the
        block-sparse kernel (which carries the mask) instead."""
        dispatch.clear_plan_cache()
        try:
            shape = (1, 1, N, D)
            for forced in ("pallas", "collapse"):
                p = resolve_plan(shape, shape, CFG, backend=forced,
                                 policy="svg")
                assert p.backend == "sparse"
                # the downgrade really executes: dispatch works end-to-end
                out = _dispatch("svg", backend=forced)
                assert np.isfinite(np.asarray(out)).all()
            # non-bias policies keep the explicit choice
            assert resolve_plan(shape, shape, CFG, backend="collapse",
                                policy="ripple").backend == "collapse"
        finally:
            dispatch.clear_plan_cache()

    def test_ripple_svg_combo_bias_kept_off_collapse(self):
        """cfg.svg_mask makes the ripple policy emit a (non-window-
        constant) bias too: auto must not resolve to collapse, and an
        explicit pallas/collapse downgrades — collapse on that bias is
        silently wrong math, pallas a trace-time crash.  The combo tiles
        its mask, so the downgrade target is the block-sparse kernel."""
        dispatch.clear_plan_cache()
        try:
            cfg = dataclasses.replace(CFG, svg_mask=True,
                                      execution="collapse")
            shape = (1, 1, N, D)
            assert resolve_plan(shape, shape, cfg).backend == "sparse"
            for forced in ("pallas", "collapse"):
                assert resolve_plan(shape, shape, cfg,
                                    backend=forced).backend == "sparse"
            # dispatch agrees with dense-with-bias on the snapped operands
            q, k, v = _qkv(8)
            out = attention_dispatch(q, k, v, grid=GRID, cfg=cfg, step=STEP,
                                     total_steps=10, backend="collapse")
            ref = attention_dispatch(q, k, v, grid=GRID, cfg=cfg, step=STEP,
                                     total_steps=10)
            np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        finally:
            dispatch.clear_plan_cache()

    def test_forced_sparse_with_external_bias_downgrades(self):
        """An explicit 'sparse' with an external caller bias must not
        reach the kernel for a map-emitting policy: its FULL tiles are
        derived from the policy's own mask and would silently drop the
        caller's bias — downgrade to reference instead."""
        dispatch.clear_plan_cache()
        try:
            shape = (1, 1, N, D)
            p = resolve_plan(shape, shape, CFG, backend="sparse",
                             has_bias=True, policy="svg")
            assert p.backend == "reference"
            # mapless policies keep forced sparse: with no block map the
            # kernel runs every tile PARTIAL, so the bias is honoured
            p = resolve_plan(shape, shape, CFG, backend="sparse",
                             has_bias=True, policy="ripple")
            assert p.backend == "sparse"
        finally:
            dispatch.clear_plan_cache()

    def test_plan_summary_names_policy(self):
        dispatch.clear_plan_cache()
        try:
            s = resolve_plan((1, 1, N, D), (1, 1, N, D), CFG,
                             policy="svg").summary()
            assert "svg" in s
        finally:
            dispatch.clear_plan_cache()


class _HalfKPolicy(ReusePolicy):
    """Out-of-tree example: snap every odd K token to its predecessor
    (a fixed stride-2 temporal collapse, no thresholds at all)."""

    name = "half_k_test"

    def decide(self, q, k, *, grid, cfg, thetas, bias=None, grid_slice=None,
               fused=False):
        idx = jnp.arange(k.shape[-2])
        src = (idx // 2) * 2
        k_s = jnp.take(k, src, axis=-2)
        k_mask = jnp.broadcast_to((idx % 2 == 1)[:, None], k.shape)
        return ReuseDecision(q=q, k=k_s, thetas=thetas, active_axes=("t",),
                             bias=bias, q_mask=jnp.zeros(q.shape, jnp.bool_),
                             k_mask=k_mask,
                             savings=jnp.mean(k_mask.astype(jnp.float32)),
                             window=cfg.window)


class TestOutOfTreeRegistration:
    """The acceptance path: a new policy registers and serves end-to-end
    without any edit to core/dispatch.py."""

    @pytest.fixture
    def half_k(self):
        pol = register_policy(_HalfKPolicy(), override=True)
        yield pol
        policy_lib._REGISTRY.pop("half_k_test", None)
        dispatch.clear_plan_cache()

    def test_dispatch_accepts_custom_policy(self, half_k):
        q, k, v = _qkv(7)
        out = _dispatch("half_k_test", seed=7)
        idx = np.arange(N)
        k_s = np.asarray(k)[..., (idx // 2) * 2, :]
        ref = dense_attention(q, jnp.asarray(k_s), v, 1.0 / np.sqrt(D))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6)
        _, st = _dispatch("half_k_test", seed=7, with_stats=True)
        assert float(st.savings) > 0.2

    def test_served_end_to_end_by_policy_bucket(self, half_k):
        """DiffusionEngine routes per-request policies to per-policy
        buckets; the factory receives the policy name and serves it."""
        from repro.serving.engine import DiffusionEngine, GenRequest

        built = []

        def factory(latent_shape, steps, policy):
            built.append(policy)
            cfg = dataclasses.replace(CFG, policy=policy or "ripple")

            def fn(noise, txt, rngs):
                B = noise.shape[0]
                q = jnp.broadcast_to(noise[:, None], (B, 1) + noise.shape[1:])
                out = attention_dispatch(q, q, q, grid=GRID, cfg=cfg,
                                         step=STEP, total_steps=10)
                return out[:, 0]
            return fn

        eng = DiffusionEngine(sampler_factory=factory, max_batch=2,
                              max_wait_s=0.01)
        eng.start()
        lat = (N, D)
        for rid, pol in enumerate(("half_k_test", "ripple",
                                   "half_k_test", None)):
            eng.submit(GenRequest(request_id=rid, txt=np.zeros((1, 1),
                                                              np.float32),
                                  steps=2, seed=rid, latent_shape=lat,
                                  policy=pol))
        outs = [eng.result(i, timeout=60) for i in range(4)]
        eng.stop()
        assert sorted(built, key=str) == [None, "half_k_test", "ripple"]
        assert all(o.latents.shape == lat for o in outs)
        # both half_k_test requests share one bucket -> same output for
        # the same seed-independent sampler input shape
        assert len(eng._compiled) == 3

    def test_legacy_decide_signature_survives_forced_sparse(self, half_k):
        """A pre-§12 policy whose decide() lacks the block_shape kwarg
        must not crash under a forced 'sparse' backend — the dispatcher
        only passes block_shape to map-emitting policies, and a mapless
        decision runs the kernel's all-full path."""
        q, k, v = _qkv(7)
        out = _dispatch("half_k_test", seed=7, backend="sparse")
        ref = _dispatch("half_k_test", seed=7, backend="reference")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)

    def test_policy_refused_when_factory_cannot_honour_it(self):
        """A legacy 2-arg factory can't build per-policy samplers;
        serving the default strategy under a policy-tagged bucket would
        be silent misrouting, so the engine refuses up front."""
        from repro.serving.engine import DiffusionEngine, GenRequest

        eng = DiffusionEngine(sampler_factory=lambda shape, steps:
                              (lambda n, t, r: n))
        with pytest.raises(ValueError, match="policy"):
            eng.submit(GenRequest(request_id=0,
                                  txt=np.zeros((1, 1), np.float32),
                                  latent_shape=(2,), policy="svg"))
        with pytest.raises(ValueError, match="default_policy"):
            DiffusionEngine(sampler_factory=lambda shape, steps:
                            (lambda n, t, r: n), default_policy="svg")
