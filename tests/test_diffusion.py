"""Diffusion substrate tests: schedules, samplers, serving engine, and
the end-to-end denoise loop with TimeRipple's step-indexed thresholds."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import RippleConfig
from repro.diffusion.sampler import cfg_wrap, ddim_sample, euler_flow_sample
from repro.diffusion.schedule import DDPMSchedule, RectifiedFlowSchedule
from repro.data.synthetic import correlated_video_latents
from repro.serving.engine import DiffusionEngine, GenRequest, LMEngine


class TestSchedules:
    def test_ddpm_alpha_bars_monotone(self):
        sch = DDPMSchedule()
        ab = np.asarray(sch.alpha_bars())
        assert (np.diff(ab) < 0).all() and 0 < ab[-1] < ab[0] < 1

    def test_add_noise_snr(self):
        sch = DDPMSchedule()
        x0 = jnp.ones((2, 4, 4, 1))
        noise = jnp.zeros_like(x0)
        t = jnp.asarray([0, 999])
        xt = sch.add_noise(x0, noise, t)
        ab = np.asarray(sch.alpha_bars())
        np.testing.assert_allclose(np.asarray(xt[0]), np.sqrt(ab[0]),
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(xt[1]), np.sqrt(ab[999]),
                                   rtol=1e-5)

    def test_rf_interpolation_endpoints(self):
        rf = RectifiedFlowSchedule()
        x0 = jnp.ones((2, 4))
        n = -jnp.ones((2, 4))
        np.testing.assert_allclose(
            np.asarray(rf.interpolate(x0, n, jnp.zeros((2,)))), 1.0)
        np.testing.assert_allclose(
            np.asarray(rf.interpolate(x0, n, jnp.ones((2,)))), -1.0)


class TestSamplers:
    def test_ddim_exact_with_true_eps(self):
        """With a perfect noise predictor, deterministic DDIM inverts the
        forward process exactly."""
        sch = DDPMSchedule()
        x0 = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 1))
        eps = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 1))
        ab_T = sch.alpha_bars()[-1]
        x_T = jnp.sqrt(ab_T) * x0 + jnp.sqrt(1 - ab_T) * eps
        out = ddim_sample(lambda x, t, s: eps, x_T, sch, num_steps=50)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x0),
                                   atol=1e-3)

    def test_euler_flow_exact_with_true_velocity(self):
        """Rectified-flow paths are straight; Euler with the true velocity
        recovers x0 exactly in any number of steps."""
        x0 = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 1))
        noise = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 1))
        v = noise - x0
        out = euler_flow_sample(lambda x, t, s: v, noise, num_steps=4)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x0),
                                   atol=1e-5)

    def test_cfg_wrap_combines(self):
        def fn(x, t, s):
            B = x.shape[0] // 2
            return jnp.concatenate([jnp.zeros((B, 2)), jnp.ones((B, 2))])
        out = cfg_wrap(fn, guidance=3.0)(jnp.zeros((2, 2)),
                                         jnp.zeros((2,)), 0)
        np.testing.assert_allclose(np.asarray(out), 3.0)

    def test_sampler_threads_step_index(self):
        """The step index reaching the denoiser is what drives Eq. 4."""
        seen = []

        def fn(x, t, s):
            seen.append(int(s))
            return jnp.zeros_like(x)

        sch = DDPMSchedule()
        with jax.disable_jit():
            ddim_sample(fn, jnp.zeros((1, 2, 2, 1)), sch, num_steps=5)
        assert seen == [0, 1, 2, 3, 4]


class TestChunkedSampling:
    """``step_offset`` / ``total_steps`` slicing (DESIGN.md §15.3):
    running the denoising scan in chunks, feeding each chunk's output
    into the next, reproduces the monolithic result exactly — the
    timestep table is built from ``total_steps`` and indexed by absolute
    step, so the per-step math never changes."""

    @staticmethod
    def _eps_fn(x, t, s):
        # depends on both x and t so any step-indexing slip would show
        return 0.1 * x + 0.01 * t.astype(jnp.float32).reshape(
            (-1,) + (1,) * (x.ndim - 1))

    def test_ddim_chunks_match_monolithic(self):
        sch = DDPMSchedule()
        x_T = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 1))
        full = ddim_sample(self._eps_fn, x_T, sch, num_steps=5)
        x = x_T
        for s0 in range(0, 5, 2):  # chunks of 2, 2, 1
            x = ddim_sample(self._eps_fn, x, sch,
                            num_steps=min(2, 5 - s0), step_offset=s0,
                            total_steps=5)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(full))

    def test_euler_chunks_match_monolithic(self):
        x_T = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 4, 1))
        full = euler_flow_sample(self._eps_fn, x_T, num_steps=6)
        x = x_T
        for s0 in range(0, 6, 4):  # uneven chunks of 4, 2
            x = euler_flow_sample(self._eps_fn, x,
                                  num_steps=min(4, 6 - s0),
                                  step_offset=s0, total_steps=6)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(full))

    def test_traced_step_offset_single_compiled_chunk(self):
        """One jitted chunk program serves every offset: step_offset is
        a traced scalar, only the chunk length is static."""
        import functools

        sch = DDPMSchedule()
        x_T = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 4, 1))

        @functools.partial(jax.jit, static_argnames=("count",))
        def chunk(x, s0, *, count):
            return ddim_sample(self._eps_fn, x, sch, num_steps=count,
                               step_offset=s0, total_steps=6)

        full = ddim_sample(self._eps_fn, x_T, sch, num_steps=6)
        x = x_T
        for s0 in range(0, 6, 3):
            x = chunk(x, jnp.asarray(s0, jnp.int32), count=3)
        np.testing.assert_allclose(np.asarray(x), np.asarray(full),
                                   rtol=1e-6, atol=1e-6)


class TestSyntheticRedundancy:
    def test_correlation_knobs_control_reuse(self):
        """Higher temporal_rho must produce more snapping at fixed θ —
        the property that makes the synthetic data a valid testbed for
        the paper's claims."""
        from repro.core import reuse
        grid = (8, 8, 8)
        th = {a: jnp.asarray(0.3) for a in ("t", "x", "y")}
        fracs = []
        for rho in (0.0, 0.9, 0.99):
            lat = correlated_video_latents(
                jax.random.PRNGKey(0), 1, grid, 8, temporal_rho=rho)
            x = lat.reshape(1, -1, 8)
            r = reuse.compute_reuse(x, grid, th, axes=("t",))
            fracs.append(float(r.mask.mean()))
        assert fracs[0] < fracs[1] < fracs[2]


class TestServingEngines:
    def test_diffusion_engine_batches_and_returns(self):
        calls = []

        def sample_fn(noise, txt, rng):
            calls.append(noise.shape[0])
            return noise * 0 + txt[:, 0, 0][:, None, None, None]

        eng = DiffusionEngine(sample_fn, latent_shape=(4, 4, 1),
                              max_batch=4, max_wait_s=0.2)
        eng.start()
        for i in range(4):
            txt = np.full((2, 3), float(i), np.float32)
            eng.submit(GenRequest(request_id=i, txt=txt, seed=i))
        for i in range(4):
            r = eng.result(i, timeout=30)
            np.testing.assert_allclose(r.latents, float(i))
        eng.stop()
        assert sum(calls) == 4  # every request served exactly once

    def test_lm_engine_matches_full_forward(self):
        from repro.configs import get_smoke_config
        from repro.models import transformer_lm as lm
        from repro.models.params import init_params

        arch = get_smoke_config("qwen3-32b")
        cfg = arch.model
        params = init_params(lm.lm_defs(cfg), jax.random.PRNGKey(0))
        eng = LMEngine(
            prefill_fn=lambda toks: lm.lm_prefill(
                params, toks, cfg, max_len=32, compute_dtype=jnp.float32),
            decode_fn=lambda tok, cache, idx: lm.lm_decode_step(
                params, tok, cache, idx, cfg, compute_dtype=jnp.float32),
            max_len=32)
        prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0,
                                    cfg.vocab_size)
        gen = eng.generate(prompt, num_new=4)
        # oracle: greedy over repeated full forwards
        seq = prompt
        for _ in range(4):
            logits, _, _ = lm.lm_apply(params, seq, cfg,
                                       compute_dtype=jnp.float32)
            nxt = jnp.argmax(logits[:, -1], axis=-1)
            seq = jnp.concatenate([seq, nxt[:, None]], axis=1)
        np.testing.assert_array_equal(np.asarray(gen),
                                      np.asarray(seq[:, 5:]))
