"""Context-parallel ring attention tier (DESIGN.md §14).

Property-based parity: the ring path (token axis sharded over the
``seq`` mesh axis) must match the single-device dispatch for random
grids, windows, and policies at 2/4/8-way seq shards — bitwise for the
snap policies (ripple, equal_mse) and for dense's fallback, and to the
documented ~1e-5 relative tolerance for svg (hop order rotates the
online-softmax reduction per shard).  The fixed-example fallback in
``_hypothesis_compat`` keeps the properties spot-checked when
``hypothesis`` is absent.

Also here, always-on (single-device): the sparse kernel's ring-hop
carry convention — chaining calls over K column slices equals one
full-width call bitwise, and a fully-masked query row finalizes to
zeros, never NaN.  Multi-device tests skip unless the backend exposes
enough devices (CI's multi-device job forces 8 virtual CPU devices).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade to fixed-example property checks
    from _hypothesis_compat import given, settings, st

from conftest import require_devices
from repro.config.base import RippleConfig
from repro.core import decision_cache as dc
from repro.core import dispatch
from repro.core.dispatch import (attention_dispatch, dispatch_mesh,
                                 resolve_plan)

# Grid/window pairs the property sweep draws from: (4,4,4)@4-way puts
# the window across a whole shard (t_local=1 < window, the multi-hop
# halo case), (8,4,4) has a window-misaligned shard boundary at 3, and
# (8,8,8) divides evenly at every way count.
GRIDS = [(4, 4, 4), (8, 4, 4), (8, 8, 8)]
WINDOWS = (2, 3, 2)
# Order matters for the fixed-example fallback (it draws lo/mid/hi =
# indices 0, 1, 3): ripple, equal_mse and svg must all be hit; dense's
# never-rings fallback has its own test below.
POLICIES = ("ripple", "equal_mse", "dense", "svg")
# Snap policies ring only on the reference backend (the bitwise
# contract); svg auto-resolves to the sparse backend.
BACKENDS = {"ripple": "reference", "equal_mse": "reference",
            "svg": None, "dense": None}


def _cfg(window=2, **kw):
    return RippleConfig(enabled=True, theta_min=0.2, theta_max=0.5,
                        i_min=2, i_max=6, window=window, **kw)


def _qkv(seed, n, d=16, lead=(2, 2)):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return tuple(jax.random.normal(k, (*lead, n, d)) for k in ks)


def _seq_mesh(ways):
    return jax.make_mesh((1, 1, ways), ("data", "model", "seq"))


def _run(q, k, v, grid, cfg, policy, backend, step=5):
    return np.asarray(attention_dispatch(
        q, k, v, grid=grid, cfg=cfg, step=jnp.asarray(step),
        total_steps=10, policy=policy, backend=backend))


@pytest.mark.parametrize("ways", [2, 4, 8])
class TestRingParity:
    @settings(max_examples=9, deadline=None)
    @given(gi=st.integers(0, 2), pi=st.integers(0, 3))
    def test_matches_single_device(self, ways, gi, pi):
        require_devices(ways)
        grid, window = GRIDS[gi], WINDOWS[gi]
        policy = POLICIES[pi]
        backend = BACKENDS[policy]
        cfg = _cfg(window=window)
        n = grid[0] * grid[1] * grid[2]
        q, k, v = _qkv(17 * gi + pi, n)
        dispatch.clear_plan_cache()
        ref = _run(q, k, v, grid, cfg, policy, backend)
        with dispatch_mesh(_seq_mesh(ways)):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, cfg, backend=backend,
                                policy=policy, grid=grid)
            expect_ring = (policy != "dense" and grid[0] % ways == 0)
            assert (plan.seq_shards == ways) == expect_ring, plan.summary()
            out = _run(q, k, v, grid, cfg, policy, backend)
        if expect_ring and policy == "svg":
            # hop order rotates the softmax reduction (DESIGN.md §14)
            np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-6)
        else:
            np.testing.assert_array_equal(out, ref)


class TestWindowLargerThanShard:
    def test_multi_hop_halo_bitwise(self):
        """(4,4,4) at 4-way: one frame per shard, window 2 — the halo
        exchange needs a whole neighbor block per side, and the decision
        must still be bitwise-equal to single-device."""
        require_devices(4)
        grid, n = (4, 4, 4), 64
        cfg = _cfg(window=2)
        q, k, v = _qkv(23, n)
        dispatch.clear_plan_cache()
        ref = _run(q, k, v, grid, cfg, "ripple", "reference")
        with dispatch_mesh(_seq_mesh(4)):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, cfg, backend="reference",
                                policy="ripple", grid=grid)
            assert plan.seq_shards == 4
            out = _run(q, k, v, grid, cfg, "ripple", "reference")
        np.testing.assert_array_equal(out, ref)


class TestElidedHops:
    def test_svg_ring_elides_dead_hops(self):
        """With random operands every head classifies spatial, so the
        shard hops that carry neither the sink frame nor local frames
        are all-SKIP — the ring must skip them and count them."""
        require_devices(2)
        grid, n = (8, 8, 8), 512
        cfg = dataclasses.replace(_cfg(), reuse_every=2)
        q, k, v = _qkv(3, n)
        with dispatch_mesh(_seq_mesh(2)):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, cfg, policy="svg",
                                grid=grid)
            assert plan.seq_shards == 2 and plan.backend == "sparse"
            state = dc.initial_state(q.shape, grid=grid, cfg=cfg,
                                     policy="svg", backend="sparse")
            for s in range(3):
                out, state = attention_dispatch(
                    q, k, v, grid=grid, cfg=cfg, step=jnp.asarray(s),
                    total_steps=6, policy="svg", cached_decision=state,
                    return_decision=True)
        elided = np.asarray(state.elided)
        assert elided.shape == (2,)  # one running counter per seq shard
        assert elided.sum() > 0
        assert (elided <= 3 * 2).all()  # <= steps x hops per shard

    def test_svg_hit_replays_bitwise(self):
        """A cache-hit step re-applies the cached bias verbatim, so with
        identical inputs the hit output equals a forced refresh bitwise
        — the §13 replay contract extended to the ring."""
        require_devices(2)
        grid, n = (8, 8, 8), 512
        q, k, v = _qkv(11, n)
        outs = {}
        with dispatch_mesh(_seq_mesh(2)):
            for every in (2, 1):  # step 1: cache hit vs forced refresh
                cfg = dataclasses.replace(_cfg(), reuse_every=every)
                dispatch.clear_plan_cache()
                state = dc.initial_state(q.shape, grid=grid, cfg=cfg,
                                         policy="svg", backend="sparse")
                for s in range(2):
                    out, state = attention_dispatch(
                        q, k, v, grid=grid, cfg=cfg, step=jnp.asarray(s),
                        total_steps=6, policy="svg",
                        cached_decision=state, return_decision=True)
                outs[every] = np.asarray(out)
        np.testing.assert_array_equal(outs[2], outs[1])


class TestDriftRefreshIndependence:
    def test_one_shard_refresh_does_not_desync_others(self):
        """Regression (DESIGN.md §14): a drift-forced refresh on one seq
        shard must stay local — the other shard keeps replaying its
        cached plan, bitwise-untouched, and only its hit counter moves.
        Collectives run outside the refresh cond, which is what makes
        this safe."""
        require_devices(2)
        grid, n = (4, 4, 4), 64
        cfg = dataclasses.replace(_cfg(window=2), drift_tol=0.5,
                                  reuse_every=10)
        q, k, v = _qkv(9, n)
        with dispatch_mesh(_seq_mesh(2)):
            dispatch.clear_plan_cache()
            plan = resolve_plan(q.shape, v.shape, cfg, backend="reference",
                                policy="ripple", grid=grid)
            assert plan.seq_shards == 2
            state = dc.initial_state(q.shape, grid=grid, cfg=cfg,
                                     policy="ripple", backend="reference")
            _, s1 = attention_dispatch(
                q, k, v, grid=grid, cfg=cfg, step=jnp.asarray(0),
                total_steps=20, backend="reference", policy="ripple",
                cached_decision=state, return_decision=True)
            # Perturb only the second shard's token slice: its drift
            # statistic blows past drift_tol, the first shard's doesn't.
            q2 = q.at[..., n // 2:, :].multiply(5.0)
            k2 = k.at[..., n // 2:, :].multiply(5.0)
            _, s2 = attention_dispatch(
                q2, k2, v, grid=grid, cfg=cfg, step=jnp.asarray(1),
                total_steps=20, backend="reference", policy="ripple",
                cached_decision=s1, return_decision=True)
        refr, hits = np.asarray(s2.refreshes), np.asarray(s2.hits)
        assert (refr[..., 1] == 2).all()  # perturbed shard refreshed
        assert (refr[..., 0] == 1).all()  # the other shard did not
        assert (hits[..., 0] == 1).all()  # ... it replayed its plan
        assert (hits[..., 1] == 0).all()
        # and its cached snap-source rows are bitwise-untouched
        np.testing.assert_array_equal(
            np.asarray(s2.q_idx)[..., : n // 2, :],
            np.asarray(s1.q_idx)[..., : n // 2, :])


class TestKernelCarry:
    """Single-device contracts the ring executors are built on —
    always-on tier-1, no multi-device backend needed."""

    def test_hop_chain_equals_full_width_call(self):
        """Chaining the sparse kernel over aligned K column slices via
        the (m, l, acc) carry equals one full-width call bitwise — the
        online-softmax recurrence visits the same blocks in the same
        order either way."""
        from repro.kernels.sparse.ops import sparse_attention_pallas

        n, d = 16, 8
        q, k, v = _qkv(7, n, d=d, lead=(1, 2))
        full = np.asarray(sparse_attention_pallas(q, k, v, block_q=4,
                                                  block_k=4))
        state, out = None, None
        for lo, hi in ((0, 8), (8, 16)):
            out, state = sparse_attention_pallas(
                q, k[..., lo:hi, :], v[..., lo:hi, :], block_q=4,
                block_k=4, carry=state, return_state=True)
        np.testing.assert_array_equal(np.asarray(out), full)

    def test_fully_masked_query_row_is_zeros_not_nan(self):
        """A query row whose bias is -inf everywhere accumulates l=0;
        both the kernel's own finalize and the ring's cross-hop
        ``acc / where(l > 0, l, 1)`` must emit zeros, not NaN."""
        from repro.kernels.sparse.ops import (block_map_from_keep,
                                              sparse_attention_pallas)

        n, d = 8, 4
        q, k, v = _qkv(5, n, d=d, lead=(1, 1))
        keep = jnp.ones((n, n), bool).at[2].set(False)
        bias = jnp.where(keep, 0.0, -jnp.inf).astype(jnp.float32)
        bmap = block_map_from_keep(keep, 4, 4)
        out, (m, l, acc) = sparse_attention_pallas(
            q, k, v, bias=bias, block_map=bmap, block_q=4, block_k=4,
            return_state=True)
        final = acc / jnp.where(l > 0.0, l, 1.0)[..., None]
        for arr in (np.asarray(out), np.asarray(final)):
            assert np.isfinite(arr).all()
            np.testing.assert_array_equal(arr[0, 0, 2], 0.0)
